//! Native rust implementations of the score computation (Algorithm 1).
//!
//! The lowered HLO uses the masked-dense formulation (one executable per
//! model, `k` a runtime input). The *computational-savings* claims of §5
//! cannot be observed through a masked dense product, so this module
//! implements the literal algorithm — gather the top-k dims, compute an
//! O((i+1)·k) sparse dot against the gathered key columns — and the dense
//! baseline, for the break-even benches. Equivalence of the three
//! formulations is property-tested.

use crate::tensor::topk::topk_indices_by_abs;

/// Dense baseline: S = q·Kᵀ. `keys` is row-major [seq, d].
pub fn dense_scores(q: &[f32], keys: &[f32], seq: usize, d: usize, out: &mut [f32]) {
    debug_assert_eq!(q.len(), d);
    debug_assert!(keys.len() >= seq * d && out.len() >= seq);
    for s in 0..seq {
        let krow = &keys[s * d..(s + 1) * d];
        let mut acc = 0.0f32;
        for i in 0..d {
            acc += q[i] * krow[i];
        }
        out[s] = acc;
    }
}

/// AQUA sparse scores, Algorithm 1 literal: select top-k dims of |q|,
/// then S̃ = q[I]·K[:, I]ᵀ — O(d) selection + O(seq·k) dot products.
pub fn aqua_scores_sparse(q: &[f32], keys: &[f32], seq: usize, d: usize, k: usize,
                          out: &mut [f32]) {
    let idx = topk_indices_by_abs(q, k);
    let qk: Vec<f32> = idx.iter().map(|&i| q[i]).collect();
    for s in 0..seq {
        let krow = &keys[s * d..(s + 1) * d];
        let mut acc = 0.0f32;
        for (j, &i) in idx.iter().enumerate() {
            acc += qk[j] * krow[i];
        }
        out[s] = acc;
    }
}

/// AQUA with a *pre-gathered* key cache (keys stored column-sliced as
/// [seq, k] for the chosen index set): the memory-layout the TPU mapping
/// prefers (contiguous reads). Used by the perf benches to separate
/// gather cost from dot-product cost.
pub fn aqua_scores_packed(qk: &[f32], keys_packed: &[f32], seq: usize, k: usize,
                          out: &mut [f32]) {
    for s in 0..seq {
        let krow = &keys_packed[s * k..(s + 1) * k];
        let mut acc = 0.0f32;
        for j in 0..k {
            acc += qk[j] * krow[j];
        }
        out[s] = acc;
    }
}

/// Masked-dense formulation (what the HLO computes): zero the dropped dims,
/// full-width dot. Numerically identical to the sparse gather.
pub fn aqua_scores_masked(q: &[f32], mask: &[f32], keys: &[f32], seq: usize, d: usize,
                          out: &mut [f32]) {
    let qm: Vec<f32> = q.iter().zip(mask).map(|(x, m)| x * m).collect();
    dense_scores(&qm, keys, seq, d, out);
}

/// Gather keys into the packed layout for `aqua_scores_packed`.
pub fn pack_keys(keys: &[f32], seq: usize, d: usize, idx: &[usize]) -> Vec<f32> {
    let k = idx.len();
    let mut out = vec![0.0f32; seq * k];
    for s in 0..seq {
        let krow = &keys[s * d..(s + 1) * d];
        for (j, &i) in idx.iter().enumerate() {
            out[s * k + j] = krow[i];
        }
    }
    out
}

/// Project a vector: v·P with P row-major [d, d] — the per-step O(d²)
/// overhead in the §5 cost model.
pub fn project(v: &[f32], p: &[f32], d: usize, out: &mut [f32]) {
    for j in 0..d {
        out[j] = 0.0;
    }
    for (i, &vi) in v.iter().enumerate().take(d) {
        let prow = &p[i * d..(i + 1) * d];
        for j in 0..d {
            out[j] += vi * prow[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::topk::{threshold_mask_by_abs, topk_mask_by_abs};
    use crate::util::testkit::check;

    #[test]
    fn prop_sparse_equals_masked_equals_packed() {
        check(
            "score-formulation-equivalence",
            100,
            |g| {
                let d = 2 + g.rng.below(30);
                let seq = 1 + g.rng.below(40);
                let k = 1 + g.rng.below(d);
                let q = g.vec_f32(d, 1.0);
                let keys = g.vec_f32(seq * d, 1.0);
                (q, keys, seq, d, k)
            },
            |(q, keys, seq, d, k)| {
                let (seq, d, k) = (*seq, *d, *k);
                let mut a = vec![0.0; seq];
                let mut b = vec![0.0; seq];
                let mut c = vec![0.0; seq];
                aqua_scores_sparse(q, keys, seq, d, k, &mut a);
                let mask = topk_mask_by_abs(q, k);
                aqua_scores_masked(q, &mask, keys, seq, d, &mut b);
                let idx = topk_indices_by_abs(q, k);
                let qk: Vec<f32> = idx.iter().map(|&i| q[i]).collect();
                let packed = pack_keys(keys, seq, d, &idx);
                aqua_scores_packed(&qk, &packed, seq, k, &mut c);
                for s in 0..seq {
                    if (a[s] - b[s]).abs() > 1e-4 || (a[s] - c[s]).abs() > 1e-4 {
                        return Err(format!("mismatch at {s}: {} {} {}", a[s], b[s], c[s]));
                    }
                }
                // threshold formulation agrees too (no ties in gaussian data)
                let tm = threshold_mask_by_abs(q, k);
                let mut t = vec![0.0; seq];
                aqua_scores_masked(q, &tm, keys, seq, d, &mut t);
                for s in 0..seq {
                    if (a[s] - t[s]).abs() > 1e-4 {
                        return Err(format!("threshold mismatch at {s}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn k_equals_d_is_dense() {
        let q = [1.0f32, -2.0, 3.0];
        let keys = [0.5f32, 1.0, -1.0, 2.0, 0.0, 1.0];
        let mut a = vec![0.0; 2];
        let mut b = vec![0.0; 2];
        dense_scores(&q, &keys, 2, 3, &mut a);
        aqua_scores_sparse(&q, &keys, 2, 3, 3, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn projection_identity() {
        let d = 4;
        let mut p = vec![0.0f32; d * d];
        for i in 0..d {
            p[i * d + i] = 1.0;
        }
        let v = [1.0f32, 2.0, 3.0, 4.0];
        let mut out = [0.0f32; 4];
        project(&v, &p, d, &mut out);
        assert_eq!(out, v);
    }

    #[test]
    fn prop_orthogonal_projection_preserves_dot(/* Lemma A.4 */) {
        use crate::tensor::svd::projection_from_data;
        use crate::tensor::Tensor;
        check(
            "rotational-invariance",
            25,
            |g| {
                let d = 2 + g.rng.below(10);
                let data = Tensor::new(&[32, d], g.vec_f32(32 * d, 1.0)).unwrap();
                let q = g.vec_f32(d, 1.0);
                let kk = g.vec_f32(d, 1.0);
                (data, q, kk, d)
            },
            |(data, q, kk, d)| {
                let d = *d;
                let p = projection_from_data(data).map_err(|e| e.to_string())?;
                let mut qh = vec![0.0; d];
                let mut kh = vec![0.0; d];
                project(q, p.data(), d, &mut qh);
                project(kk, p.data(), d, &mut kh);
                let orig: f32 = q.iter().zip(kk.iter()).map(|(a, b)| a * b).sum();
                let rot: f32 = qh.iter().zip(kh.iter()).map(|(a, b)| a * b).sum();
                if (orig - rot).abs() < 1e-3 * orig.abs().max(1.0) {
                    Ok(())
                } else {
                    Err(format!("dot changed: {orig} vs {rot}"))
                }
            },
        );
    }
}
