//! Native rust implementations of the score computation (Algorithm 1).
//!
//! The lowered HLO uses the masked-dense formulation (one executable per
//! model, `k` a runtime input). The *computational-savings* claims of §5
//! cannot be observed through a masked dense product, so this module
//! implements the literal algorithm — gather the top-k dims, compute an
//! O((i+1)·k) sparse dot against the gathered key columns — and the dense
//! baseline, for the break-even benches. Equivalence of the three
//! formulations is property-tested.

use crate::tensor::topk::topk_indices_by_abs;

/// Dense baseline: S = q·Kᵀ. `keys` is row-major [seq, d].
pub fn dense_scores(q: &[f32], keys: &[f32], seq: usize, d: usize, out: &mut [f32]) {
    debug_assert_eq!(q.len(), d);
    debug_assert!(keys.len() >= seq * d && out.len() >= seq);
    for s in 0..seq {
        let krow = &keys[s * d..(s + 1) * d];
        let mut acc = 0.0f32;
        for i in 0..d {
            acc += q[i] * krow[i];
        }
        out[s] = acc;
    }
}

/// AQUA sparse scores with a *precomputed* index set and pre-gathered
/// query values (`qk[j] = q[idx[j]]`): the zero-allocation variant the
/// decode hot path and benches use. `idx` must be ascending so the
/// accumulation order matches the masked-dense formulation exactly.
pub fn aqua_scores_sparse_idx(qk: &[f32], idx: &[usize], keys: &[f32], seq: usize, d: usize,
                              out: &mut [f32]) {
    debug_assert!(qk.len() >= idx.len());
    debug_assert!(keys.len() >= seq * d && out.len() >= seq);
    for s in 0..seq {
        let krow = &keys[s * d..(s + 1) * d];
        let mut acc = 0.0f32;
        for (j, &i) in idx.iter().enumerate() {
            acc += qk[j] * krow[i];
        }
        out[s] = acc;
    }
}

/// AQUA sparse scores, Algorithm 1 literal: select top-k dims of |q|,
/// then S̃ = q[I]·K[:, I]ᵀ — O(d) selection + O(seq·k) dot products.
/// Allocating wrapper over [`aqua_scores_sparse_idx`] (kept for tests and
/// one-shot callers).
pub fn aqua_scores_sparse(q: &[f32], keys: &[f32], seq: usize, d: usize, k: usize,
                          out: &mut [f32]) {
    let idx = topk_indices_by_abs(q, k);
    let qk: Vec<f32> = idx.iter().map(|&i| q[i]).collect();
    aqua_scores_sparse_idx(&qk, &idx, keys, seq, d, out);
}

/// AQUA with a *pre-gathered* key cache (keys stored column-sliced as
/// [seq, k] for the chosen index set): the memory-layout the TPU mapping
/// prefers (contiguous reads). Used by the perf benches to separate
/// gather cost from dot-product cost.
pub fn aqua_scores_packed(qk: &[f32], keys_packed: &[f32], seq: usize, k: usize,
                          out: &mut [f32]) {
    for s in 0..seq {
        let krow = &keys_packed[s * k..(s + 1) * k];
        let mut acc = 0.0f32;
        for j in 0..k {
            acc += qk[j] * krow[j];
        }
        out[s] = acc;
    }
}

/// Packed scores over a *dim-major* (column-major) key cache: `kcols` is
/// [d, stride] with dimension i's values for every slot contiguous at
/// `kcols[i*stride..]`. For each selected dim the kernel streams one
/// contiguous run of `n` floats, so compute AND memory traffic scale with
/// k — the kernel/layout co-design that makes the §5 savings observable on
/// the decode hot path (the native analog of TurboAttention-style packed
/// operand layouts). `idx` ascending keeps the accumulation order — and
/// therefore the f32 result — bit-identical to the masked-dense oracle.
pub fn aqua_scores_packed_cols(qk: &[f32], idx: &[usize], kcols: &[f32], stride: usize,
                               n: usize, out: &mut [f32]) {
    debug_assert!(n <= stride && out.len() >= n);
    debug_assert!(qk.len() >= idx.len());
    out[..n].fill(0.0);
    for (j, &i) in idx.iter().enumerate() {
        let qv = qk[j];
        if qv == 0.0 {
            // ±0.0 contributions never change an f32 accumulator; skipping
            // them preserves bit-parity while honoring AQUA-Memory's
            // statically zeroed dims for free.
            continue;
        }
        let col = &kcols[i * stride..i * stride + n];
        for (o, &kv) in out[..n].iter_mut().zip(col) {
            *o += qv * kv;
        }
    }
}

/// Sparse scores at an explicit slot subset over the dim-major cache:
/// writes `out[s]` for `s` in `slots` only — O(|slots|·k) regardless of the
/// write cursor, the right shape once H2O has punched holes in the
/// attendable set. Bit-identical to [`aqua_scores_packed_cols`] at the
/// slots it touches (same ascending-dim accumulation order).
pub fn aqua_scores_packed_cols_at(qk: &[f32], idx: &[usize], kcols: &[f32], stride: usize,
                                  slots: &[usize], out: &mut [f32]) {
    debug_assert!(qk.len() >= idx.len());
    for &s in slots {
        let mut acc = 0.0f32;
        for (j, &i) in idx.iter().enumerate() {
            acc += qk[j] * kcols[i * stride + s];
        }
        out[s] = acc;
    }
}

/// Masked-dense formulation (what the HLO computes): zero the dropped dims,
/// full-width dot. Numerically identical to the sparse gather.
pub fn aqua_scores_masked(q: &[f32], mask: &[f32], keys: &[f32], seq: usize, d: usize,
                          out: &mut [f32]) {
    let qm: Vec<f32> = q.iter().zip(mask).map(|(x, m)| x * m).collect();
    dense_scores(&qm, keys, seq, d, out);
}

/// Gather keys into the packed layout for `aqua_scores_packed`, writing
/// into a caller-provided buffer (`out` len ≥ seq·|idx|) — no allocation.
pub fn pack_keys_into(keys: &[f32], seq: usize, d: usize, idx: &[usize], out: &mut [f32]) {
    let k = idx.len();
    debug_assert!(keys.len() >= seq * d && out.len() >= seq * k);
    for s in 0..seq {
        let krow = &keys[s * d..(s + 1) * d];
        let orow = &mut out[s * k..(s + 1) * k];
        for (o, &i) in orow.iter_mut().zip(idx) {
            *o = krow[i];
        }
    }
}

/// Allocating wrapper over [`pack_keys_into`] (tests / one-shot callers).
pub fn pack_keys(keys: &[f32], seq: usize, d: usize, idx: &[usize]) -> Vec<f32> {
    let mut out = vec![0.0f32; seq * idx.len()];
    pack_keys_into(keys, seq, d, idx, &mut out);
    out
}

/// Project a vector into a caller-provided buffer: v·P with P row-major
/// [d, d] — the per-*token* O(d²) overhead in the §5 cost model (the
/// native backend pays it once at cache-append for keys and once per step
/// per head for queries).
pub fn project(v: &[f32], p: &[f32], d: usize, out: &mut [f32]) {
    out[..d].fill(0.0);
    for (i, &vi) in v.iter().enumerate().take(d) {
        if vi == 0.0 {
            continue;
        }
        let prow = &p[i * d..(i + 1) * d];
        for (o, &pv) in out[..d].iter_mut().zip(prow) {
            *o += vi * pv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::topk::{threshold_mask_by_abs, topk_mask_by_abs};
    use crate::util::testkit::check;

    #[test]
    fn prop_sparse_equals_masked_equals_packed() {
        check(
            "score-formulation-equivalence",
            100,
            |g| {
                let d = 2 + g.rng.below(30);
                let seq = 1 + g.rng.below(40);
                let k = 1 + g.rng.below(d);
                let q = g.vec_f32(d, 1.0);
                let keys = g.vec_f32(seq * d, 1.0);
                (q, keys, seq, d, k)
            },
            |(q, keys, seq, d, k)| {
                let (seq, d, k) = (*seq, *d, *k);
                let mut a = vec![0.0; seq];
                let mut b = vec![0.0; seq];
                let mut c = vec![0.0; seq];
                aqua_scores_sparse(q, keys, seq, d, k, &mut a);
                let mask = topk_mask_by_abs(q, k);
                aqua_scores_masked(q, &mask, keys, seq, d, &mut b);
                let idx = topk_indices_by_abs(q, k);
                let qk: Vec<f32> = idx.iter().map(|&i| q[i]).collect();
                let packed = pack_keys(keys, seq, d, &idx);
                aqua_scores_packed(&qk, &packed, seq, k, &mut c);
                for s in 0..seq {
                    if (a[s] - b[s]).abs() > 1e-4 || (a[s] - c[s]).abs() > 1e-4 {
                        return Err(format!("mismatch at {s}: {} {} {}", a[s], b[s], c[s]));
                    }
                }
                // threshold formulation agrees too (no ties in gaussian data)
                let tm = threshold_mask_by_abs(q, k);
                let mut t = vec![0.0; seq];
                aqua_scores_masked(q, &tm, keys, seq, d, &mut t);
                for s in 0..seq {
                    if (a[s] - t[s]).abs() > 1e-4 {
                        return Err(format!("threshold mismatch at {s}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_colmajor_kernels_bit_match_masked_dense() {
        // The dim-major packed kernels must match the masked-dense oracle
        // *bitwise* (same ascending-dim accumulation order) — this is what
        // lets the native backend route through them while the oracle stays
        // the parity reference.
        check(
            "colmajor-bit-parity",
            100,
            |g| {
                let d = 2 + g.rng.below(30);
                let seq = 1 + g.rng.below(40);
                let k = 1 + g.rng.below(d);
                let q = g.vec_f32(d, 1.0);
                let keys = g.vec_f32(seq * d, 1.0);
                (q, keys, seq, d, k)
            },
            |(q, keys, seq, d, k)| {
                let (seq, d, k) = (*seq, *d, *k);
                let mut kcols = vec![0.0f32; d * seq];
                for s in 0..seq {
                    for i in 0..d {
                        kcols[i * seq + s] = keys[s * d + i];
                    }
                }
                let idx = topk_indices_by_abs(q, k);
                let qk: Vec<f32> = idx.iter().map(|&i| q[i]).collect();
                let mask = topk_mask_by_abs(q, k);
                let mut oracle = vec![0.0; seq];
                aqua_scores_masked(q, &mask, keys, seq, d, &mut oracle);
                let mut packed = vec![0.0; seq];
                aqua_scores_packed_cols(&qk, &idx, &kcols, seq, seq, &mut packed);
                if packed != oracle {
                    return Err("packed_cols != masked-dense bitwise".into());
                }
                let slots: Vec<usize> = (0..seq).step_by(2).collect();
                let mut subset = vec![0.0; seq];
                aqua_scores_packed_cols_at(&qk, &idx, &kcols, seq, &slots, &mut subset);
                for &s in &slots {
                    if subset[s] != oracle[s] {
                        return Err(format!("packed_cols_at mismatch at slot {s}"));
                    }
                }
                let mut sparse = vec![0.0; seq];
                aqua_scores_sparse_idx(&qk, &idx, keys, seq, d, &mut sparse);
                if sparse != oracle {
                    return Err("sparse_idx != masked-dense bitwise".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn k_equals_d_is_dense() {
        let q = [1.0f32, -2.0, 3.0];
        let keys = [0.5f32, 1.0, -1.0, 2.0, 0.0, 1.0];
        let mut a = vec![0.0; 2];
        let mut b = vec![0.0; 2];
        dense_scores(&q, &keys, 2, 3, &mut a);
        aqua_scores_sparse(&q, &keys, 2, 3, 3, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn projection_identity() {
        let d = 4;
        let mut p = vec![0.0f32; d * d];
        for i in 0..d {
            p[i * d + i] = 1.0;
        }
        let v = [1.0f32, 2.0, 3.0, 4.0];
        let mut out = [0.0f32; 4];
        project(&v, &p, d, &mut out);
        assert_eq!(out, v);
    }

    #[test]
    fn prop_orthogonal_projection_preserves_dot(/* Lemma A.4 */) {
        use crate::tensor::svd::projection_from_data;
        use crate::tensor::Tensor;
        check(
            "rotational-invariance",
            25,
            |g| {
                let d = 2 + g.rng.below(10);
                let data = Tensor::new(&[32, d], g.vec_f32(32 * d, 1.0)).unwrap();
                let q = g.vec_f32(d, 1.0);
                let kk = g.vec_f32(d, 1.0);
                (data, q, kk, d)
            },
            |(data, q, kk, d)| {
                let d = *d;
                let p = projection_from_data(data).map_err(|e| e.to_string())?;
                let mut qh = vec![0.0; d];
                let mut kh = vec![0.0; d];
                project(q, p.data(), d, &mut qh);
                project(kk, p.data(), d, &mut kh);
                let orig: f32 = q.iter().zip(kk.iter()).map(|(a, b)| a * b).sum();
                let rot: f32 = qh.iter().zip(kh.iter()).map(|(a, b)| a * b).sum();
                if (orig - rot).abs() < 1e-3 * orig.abs().max(1.0) {
                    Ok(())
                } else {
                    Err(format!("dot changed: {orig} vs {rot}"))
                }
            },
        );
    }
}
