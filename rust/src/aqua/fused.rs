//! Page-fused streaming attention (PR 10 tentpole).
//!
//! The three-pass decode path (scores over all S slots → softmax over an
//! S-length scratch → AV reduction re-walking the value rows) becomes ONE
//! streaming pass per KV page:
//!
//! ```text
//! for page in lane.pages (attendable slots only):
//!     z[0..page_slots] = packed AQUA scores of the page   (O(page) scratch)
//!     fold max(z) into the online softmax (rescale acc by alpha)
//!     for slot in page: e = exp(z - m); denom += e; acc += e · V[slot]
//! out = acc / denom
//! ```
//!
//! so each resident page is loaded **exactly once** per (layer, head,
//! token) — keys and values together, while the page is hot in cache —
//! and the kernel's own scratch is `O(page_slots)` instead of `O(S)`
//! (the flash-attention shape, folded over AQUA's truncated dim-major
//! pages). The raw scaled scores are also written once per slot into the
//! caller's S-length staging row so the engine's per-slot attention
//! accumulator (H2O's input) can be normalized afterwards without a
//! second walk over any KV page.
//!
//! Numerics:
//! * the per-page score block accumulates selected dims in ascending
//!   order with the same `q·0 = skip` convention as
//!   [`crate::aqua::native::aqua_scores_packed_cols`], so fused f32
//!   scores are **bit-identical** to the packed kernel's — only the
//!   softmax/AV association order differs (within 1e-5 of the
//!   masked-dense oracle; the parity suite pins it);
//! * SIMD is strictly **elementwise** (per-lane mul then add, the same
//!   IEEE operation sequence as the scalar loop), so lane width never
//!   changes a single bit — the masked-dense oracle stays the accuracy
//!   referee whether AVX is used or not, and native/sharded stay
//!   bit-identical on any machine;
//! * slots on never-leased pages score exactly 0.0 with a zero value row
//!   (the packed path's dense-zero semantics), and fully-masked page
//!   segments fold as identities (`OnlineSoftmax`'s -inf guard), never
//!   NaN;
//! * under [`KvQuant::Int8`] the per-page dequantization (`q · scale`) is
//!   fused into the same score/AV loop — the int8 payload is never
//!   materialized at full width, and the time spent in dequantizing
//!   passes is reported per step (`KernelCounters::dequant_ns`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

use crate::kvpool::{KvQuant, LanePageTable, PagePool};
use crate::tensor::softmax::OnlineSoftmax;

// ---------------------------------------------------------------------------
// SIMD policy (f32x8 on x86-64 AVX, scalar everywhere else)
// ---------------------------------------------------------------------------

/// 0 = unprobed, 1 = scalar, 2 = f32x8. Runtime feature detection probed
/// once; tests can force scalar to pin the bit-identity claim.
static SIMD_STATE: AtomicU8 = AtomicU8::new(0);
/// 0 = auto, 1 = forced scalar (tests / `AQUA_NO_SIMD`).
static SIMD_FORCE_SCALAR: AtomicU8 = AtomicU8::new(0);

#[cfg(target_arch = "x86_64")]
fn probe_simd() -> u8 {
    if std::arch::is_x86_feature_detected!("avx") {
        2
    } else {
        1
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn probe_simd() -> u8 {
    1
}

/// Force the scalar fallback on (or release it) — the bit-control switch
/// the parity tests flip to show SIMD on/off never changes results.
pub fn force_scalar(on: bool) {
    SIMD_FORCE_SCALAR.store(on as u8, Ordering::Relaxed);
}

/// Whether the f32x8 path is active right now.
pub fn simd_active() -> bool {
    if SIMD_FORCE_SCALAR.load(Ordering::Relaxed) == 1 {
        return false;
    }
    let mut s = SIMD_STATE.load(Ordering::Relaxed);
    if s == 0 {
        s = probe_simd();
        SIMD_STATE.store(s, Ordering::Relaxed);
    }
    s == 2
}

/// f32 lanes per SIMD op on the active path (8 with AVX, 1 scalar).
pub fn simd_lanes() -> u32 {
    if simd_active() {
        8
    } else {
        1
    }
}

/// `out[i] += a * x[i]`, elementwise. The AVX body performs the exact
/// per-element mul-then-add the scalar loop performs (no FMA, no
/// horizontal reduction), so both paths are bit-identical.
#[inline]
pub fn axpy(out: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(out.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    if simd_active() {
        // SAFETY: probed `avx` above; slices are bounds-checked inside.
        unsafe { axpy_avx(out, a, x) };
        return;
    }
    axpy_scalar(out, a, x);
}

#[inline]
fn axpy_scalar(out: &mut [f32], a: f32, x: &[f32]) {
    for (o, &xv) in out.iter_mut().zip(x) {
        *o += a * xv;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn axpy_avx(out: &mut [f32], a: f32, x: &[f32]) {
    use std::arch::x86_64::*;
    let n = out.len().min(x.len());
    let va = _mm256_set1_ps(a);
    let vec_n = n & !7;
    let (op, xp) = (out.as_mut_ptr(), x.as_ptr());
    let mut i = 0;
    while i < vec_n {
        let o = _mm256_loadu_ps(op.add(i));
        let xv = _mm256_loadu_ps(xp.add(i));
        _mm256_storeu_ps(op.add(i), _mm256_add_ps(o, _mm256_mul_ps(va, xv)));
        i += 8;
    }
    for j in vec_n..n {
        *out.get_unchecked_mut(j) += a * *x.get_unchecked(j);
    }
}

// ---------------------------------------------------------------------------
// Per-page score blocks
// ---------------------------------------------------------------------------

/// Packed AQUA scores of one page for the attendable slots `slots`
/// (absolute positions, ascending, all within this page; `base` is the
/// page's first position). `kcols` is the page's dim-major (l, g) key
/// block (`key_dims * ps`). Accumulation order per slot is ascending
/// selected dims with `q == 0` skipped — bit-identical to
/// [`crate::aqua::native::aqua_scores_packed_cols`].
pub fn page_scores_f32(
    qsel: &[f32],
    idx: &[usize],
    kcols: &[f32],
    ps: usize,
    slots: &[usize],
    base: usize,
    out: &mut [f32],
) {
    let n = slots.len();
    let out = &mut out[..n];
    out.fill(0.0);
    if n == 0 {
        return;
    }
    let lo = slots[0] - base;
    if slots[n - 1] - slots[0] + 1 == n {
        // contiguous run: stream each selected dim's column with the
        // elementwise f32x8 kernel
        for (j, &i) in idx.iter().enumerate() {
            let qv = qsel[j];
            if qv == 0.0 {
                continue;
            }
            axpy(out, qv, &kcols[i * ps + lo..i * ps + lo + n]);
        }
    } else {
        // H2O holes: gather only the live slots
        for (j, &i) in idx.iter().enumerate() {
            let qv = qsel[j];
            if qv == 0.0 {
                continue;
            }
            let col = &kcols[i * ps..(i + 1) * ps];
            for (o, &s) in out.iter_mut().zip(slots) {
                *o += qv * col[s - base];
            }
        }
    }
}

/// Int8 variant: same shape, with the block dequantization scale folded
/// out of the inner loop (`Σ q·(k_q·s) = s · Σ q·k_q`).
pub fn page_scores_i8(
    qsel: &[f32],
    idx: &[usize],
    kcols: &[i8],
    k_scale: f32,
    ps: usize,
    slots: &[usize],
    base: usize,
    out: &mut [f32],
) {
    let n = slots.len();
    let out = &mut out[..n];
    out.fill(0.0);
    if n == 0 {
        return;
    }
    for (j, &i) in idx.iter().enumerate() {
        let qv = qsel[j];
        if qv == 0.0 {
            continue;
        }
        let col = &kcols[i * ps..(i + 1) * ps];
        for (o, &s) in out.iter_mut().zip(slots) {
            *o += qv * col[s - base] as f32;
        }
    }
    for o in out.iter_mut() {
        *o *= k_scale;
    }
}

// ---------------------------------------------------------------------------
// The fused streaming pass
// ---------------------------------------------------------------------------

/// Per-call observability from one fused pass (folded into
/// `KernelCounters` by the backend).
#[derive(Debug, Default, Clone, Copy)]
pub struct FusedStats {
    /// Resident pages streamed (each exactly once).
    pub pages: u64,
    /// Nanoseconds spent in int8 dequantizing page passes.
    pub dequant_ns: u64,
}

/// One fused attention pass for one (layer, kv-head group, query head):
/// streams the lane's pages once, computing scores, online softmax, and
/// the value reduction together.
///
/// * `att` — attendable absolute slots, ascending (the engine's H2O mask
///   plus in-call causality).
/// * `page_scores` — the `O(page_slots)` scratch (caller-persistent; no
///   allocation on this path).
/// * `z_out` — S-length staging row owned by the caller: the raw scaled
///   score of every attendable slot is written exactly once, so the
///   caller can emit normalized per-slot probabilities afterwards
///   without touching any page again.
/// * `out_h` — the head's value accumulator (`head_dim`, zeroed by the
///   caller); on return it holds `Σ e·V` *unnormalized* — multiply by
///   `osm.finish()` to get the attention output.
///
/// Returns the final [`OnlineSoftmax`] state. Never-leased pages score
/// 0.0 with zero value rows (dense-zero semantics); their probability
/// mass is accounted like the packed path's.
#[allow(clippy::too_many_arguments)]
pub fn fused_attend(
    qsel: &[f32],
    idx: &[usize],
    pool: &PagePool,
    table: &LanePageTable,
    l: usize,
    g: usize,
    att: &[usize],
    scale: f32,
    page_scores: &mut [f32],
    z_out: &mut [f32],
    out_h: &mut [f32],
    stats: &mut FusedStats,
) -> OnlineSoftmax {
    let layout = *pool.layout();
    let (ps, kd, d) = (layout.page_slots, layout.key_dims, layout.head_dim);
    let ko = layout.key_off(l, g);
    let mut osm = OnlineSoftmax::new();
    let mut i = 0usize;
    while i < att.len() {
        let p = att[i] / ps;
        let mut end = i + 1;
        while end < att.len() && att[end] / ps == p {
            end += 1;
        }
        let slots = &att[i..end];
        let base = p * ps;
        match table.page(p) {
            Some(pid) => {
                stats.pages += 1;
                match layout.kv_quant {
                    KvQuant::F32 => {
                        let page = pool.page(pid);
                        page_scores_f32(
                            qsel,
                            idx,
                            &page[ko..ko + kd * ps],
                            ps,
                            slots,
                            base,
                            page_scores,
                        );
                        fold_page(&mut osm, scale, slots, page_scores, z_out, out_h, |s, e, o| {
                            let vo = layout.val_off(l, g, s - base);
                            axpy(o, e, &page[vo..vo + d]);
                        });
                    }
                    KvQuant::Int8 => {
                        let t0 = Instant::now();
                        let page = pool.page_i8(pid);
                        let (sk, sv) = (pool.k_scale(pid, l, g), pool.v_scale(pid, l, g));
                        page_scores_i8(
                            qsel,
                            idx,
                            &page[ko..ko + kd * ps],
                            sk,
                            ps,
                            slots,
                            base,
                            page_scores,
                        );
                        fold_page(&mut osm, scale, slots, page_scores, z_out, out_h, |s, e, o| {
                            // dequant fused into the AV reduction: e·(q·sv)
                            let vo = layout.val_off(l, g, s - base);
                            let a = e * sv;
                            for (ov, &q) in o.iter_mut().zip(&page[vo..vo + d]) {
                                *ov += a * q as f32;
                            }
                        });
                        stats.dequant_ns += t0.elapsed().as_nanos() as u64;
                    }
                }
            }
            None => {
                // dense-zero semantics: a never-leased page scores exactly
                // 0.0 on every attendable slot, value rows are zero — the
                // mass is accounted, the mix contributes nothing
                let alpha = osm.fold_max(0.0);
                if alpha != 1.0 {
                    for o in out_h.iter_mut() {
                        *o *= alpha;
                    }
                }
                for &s in slots {
                    z_out[s] = 0.0;
                    osm.push(0.0);
                }
            }
        }
        i = end;
    }
    osm
}

/// Fold one scored page into the online softmax + value accumulator:
/// scale scores in place, advance the running max (rescaling `out_h` by
/// alpha), then push each slot's weight and hand it to `accum_v`.
#[inline]
fn fold_page(
    osm: &mut OnlineSoftmax,
    scale: f32,
    slots: &[usize],
    page_scores: &mut [f32],
    z_out: &mut [f32],
    out_h: &mut [f32],
    mut accum_v: impl FnMut(usize, f32, &mut [f32]),
) {
    let n = slots.len();
    let mut cmax = f32::NEG_INFINITY;
    for z in page_scores[..n].iter_mut() {
        *z *= scale;
        cmax = cmax.max(*z);
    }
    let alpha = osm.fold_max(cmax);
    if alpha != 1.0 {
        for o in out_h.iter_mut() {
            *o *= alpha;
        }
    }
    for (j, &s) in slots.iter().enumerate() {
        let z = page_scores[j];
        z_out[s] = z;
        let e = osm.push(z);
        if e != 0.0 {
            accum_v(s, e, out_h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aqua::native::aqua_scores_packed_cols;
    use crate::kvpool::PoolLayout;
    use crate::tensor::softmax::softmax_inplace;
    use crate::util::prng::Rng;

    fn layout(quant: KvQuant) -> PoolLayout {
        PoolLayout {
            page_slots: 8,
            key_dims: 4,
            head_dim: 4,
            layers: 1,
            kv_heads: 1,
            kv_quant: quant,
        }
    }

    /// Pool + table with `n` written positions of seeded random KV.
    #[allow(clippy::type_complexity)]
    fn build_lane(
        quant: KvQuant,
        n: usize,
        seed: u64,
    ) -> (PagePool, LanePageTable, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let lay = layout(quant);
        let (ps, kd, d) = (lay.page_slots, lay.key_dims, lay.head_dim);
        let mut pool = PagePool::new(lay, 64);
        let mut table = LanePageTable::new(64);
        let mut rng = Rng::new(seed);
        let (mut keys, mut vals) = (vec![], vec![]);
        for pos in 0..n {
            let id = table.ensure_mut(&mut pool, pos / ps).unwrap();
            table.note_write(pos);
            let k: Vec<f32> = rng.normal_vec(kd, 1.0);
            let v: Vec<f32> = rng.normal_vec(d, 1.0);
            pool.write_token(id, 0, 0, pos % ps, &k, &v);
            keys.push(k);
            vals.push(v);
        }
        (pool, table, keys, vals)
    }

    #[test]
    fn axpy_simd_and_scalar_are_bit_identical() {
        let mut rng = Rng::new(7);
        for n in [1usize, 7, 8, 9, 31, 64, 100] {
            let x = rng.normal_vec(n, 2.0);
            let base = rng.normal_vec(n, 2.0);
            let a = rng.normal() as f32;
            let mut with = base.clone();
            force_scalar(false);
            axpy(&mut with, a, &x);
            let mut without = base.clone();
            force_scalar(true);
            axpy(&mut without, a, &x);
            force_scalar(false);
            assert_eq!(with, without, "lane width changed bits at n={n}");
        }
    }

    #[test]
    fn page_scores_match_packed_kernel_bitwise() {
        let (pool, table, _, _) = build_lane(KvQuant::F32, 8, 3);
        let lay = *pool.layout();
        let (ps, kd) = (lay.page_slots, lay.key_dims);
        let mut rng = Rng::new(4);
        let qsel = rng.normal_vec(kd, 1.0);
        let idx: Vec<usize> = (0..kd).collect();
        let pid = table.page(0).unwrap();
        let kcols = &pool.page(pid)[..kd * ps];
        let mut want = vec![0.0f32; ps];
        aqua_scores_packed_cols(&qsel, &idx, kcols, ps, ps, &mut want);
        let slots: Vec<usize> = (0..ps).collect();
        let mut got = vec![0.0f32; ps];
        page_scores_f32(&qsel, &idx, kcols, ps, &slots, 0, &mut got);
        assert_eq!(got, want, "fused page scores must be bit-identical to packed");
        // subset (H2O-holes) path agrees with the contiguous one per slot
        let sub = [1usize, 4, 6];
        let mut got_sub = vec![0.0f32; sub.len()];
        page_scores_f32(&qsel, &idx, kcols, ps, &sub, 0, &mut got_sub);
        for (j, &s) in sub.iter().enumerate() {
            assert_eq!(got_sub[j], want[s], "gather slot {s}");
        }
    }

    /// Reference three-pass attention over the same pool content.
    fn three_pass(
        qsel: &[f32],
        idx: &[usize],
        keys: &[Vec<f32>],
        vals: &[Vec<f32>],
        att: &[usize],
        scale: f32,
        d: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let n = att.iter().copied().max().map_or(0, |m| m + 1);
        let mut z = vec![f32::NEG_INFINITY; n];
        for &s in att {
            let mut acc = 0.0f32;
            if s < keys.len() {
                for (j, &i) in idx.iter().enumerate() {
                    acc += qsel[j] * keys[s][i];
                }
            }
            z[s] = acc * scale;
        }
        let mut probs: Vec<f32> = att.iter().map(|&s| z[s]).collect();
        softmax_inplace(&mut probs);
        let mut out = vec![0.0f32; d];
        let mut pr = vec![0.0f32; n];
        for (j, &s) in att.iter().enumerate() {
            pr[s] = probs[j];
            if s < vals.len() {
                for (o, &v) in out.iter_mut().zip(&vals[s]) {
                    *o += probs[j] * v;
                }
            }
        }
        (out, pr)
    }

    fn fused_vs_three_pass(quant: KvQuant, att: &[usize], tol: f32) {
        let (pool, table, keys, vals) = build_lane(quant, 20, 11);
        let lay = *pool.layout();
        let (kd, d) = (lay.key_dims, lay.head_dim);
        let mut rng = Rng::new(12);
        let qsel = rng.normal_vec(kd, 1.0);
        let idx: Vec<usize> = (0..kd).collect();
        let scale = 0.5f32;
        let mut page_scores = vec![0.0f32; lay.page_slots];
        let mut z_out = vec![0.0f32; 64 * lay.page_slots];
        let mut out_h = vec![0.0f32; d];
        let mut stats = FusedStats::default();
        let osm = fused_attend(
            &qsel, &idx, &pool, &table, 0, 0, att, scale, &mut page_scores, &mut z_out,
            &mut out_h, &mut stats,
        );
        let inv = osm.finish().expect("non-empty att");
        let (want_out, want_pr) = three_pass(&qsel, &idx, &keys, &vals, att, scale, d);
        for (i, (&got, &want)) in out_h.iter().zip(&want_out).enumerate() {
            assert!(
                (got * inv - want).abs() <= tol,
                "out[{i}] fused {} vs three-pass {want}",
                got * inv
            );
        }
        for &s in att {
            let p = (z_out[s] - osm.m).exp() * inv;
            assert!((p - want_pr[s]).abs() <= tol, "prob[{s}] {p} vs {}", want_pr[s]);
        }
        // every resident page with an attendable slot was read exactly once
        let resident: usize = {
            let ps = lay.page_slots;
            let mut pages: Vec<usize> =
                att.iter().map(|&s| s / ps).filter(|&p| table.page(p).is_some()).collect();
            pages.dedup();
            pages.len()
        };
        assert_eq!(stats.pages, resident as u64, "each resident page streamed once");
    }

    #[test]
    fn fused_matches_three_pass_f32_contiguous_and_with_holes() {
        let att: Vec<usize> = (0..20).collect();
        fused_vs_three_pass(KvQuant::F32, &att, 1e-5);
        // H2O holes: drop whole pages and scattered slots
        let holey: Vec<usize> = (0..20).filter(|s| s % 3 != 1 && !(8..16).contains(s)).collect();
        fused_vs_three_pass(KvQuant::F32, &holey, 1e-5);
    }

    #[test]
    fn fused_int8_stays_within_the_quantization_bound() {
        // int8 K and V: the error of the fused output is bounded by the
        // measured block scales, far looser than f32 parity but measured
        let att: Vec<usize> = (0..20).collect();
        fused_vs_three_pass(KvQuant::Int8, &att, 0.25);
    }

    #[test]
    fn unleased_pages_score_dense_zero() {
        // att extends past the written range into a page the table never
        // leased: those slots take score 0.0 (mass accounted, zero value),
        // exactly the packed path's semantics for never-written slots
        let (pool, table, keys, vals) = build_lane(KvQuant::F32, 8, 21);
        let lay = *pool.layout();
        let (kd, d) = (lay.key_dims, lay.head_dim);
        let qsel = vec![1.0f32; kd];
        let idx: Vec<usize> = (0..kd).collect();
        let att: Vec<usize> = (0..24).collect(); // pages 1, 2 never leased
        let mut page_scores = vec![0.0f32; lay.page_slots];
        let mut z_out = vec![9.0f32; 64];
        let mut out_h = vec![0.0f32; d];
        let mut stats = FusedStats::default();
        let osm = fused_attend(
            &qsel, &idx, &pool, &table, 0, 0, &att, 1.0, &mut page_scores, &mut z_out,
            &mut out_h, &mut stats,
        );
        assert_eq!(stats.pages, 1, "only the single resident page streamed");
        let inv = osm.finish().unwrap();
        for s in 8..24 {
            assert_eq!(z_out[s], 0.0, "unleased slot {s} scores dense zero");
        }
        let (want_out, want_pr) = three_pass(&qsel, &idx, &keys, &vals, &att, 1.0, d);
        for (got, want) in out_h.iter().zip(&want_out) {
            assert!((got * inv - want).abs() < 1e-5);
        }
        assert!((((z_out[9] - osm.m).exp() * inv) - want_pr[9]).abs() < 1e-6);
        assert!(!out_h.iter().any(|x| x.is_nan()), "dense-zero fold must not NaN");
    }

    #[test]
    fn fused_results_are_simd_invariant() {
        let (pool, table, _, _) = build_lane(KvQuant::F32, 20, 31);
        let lay = *pool.layout();
        let (kd, d) = (lay.key_dims, lay.head_dim);
        let mut rng = Rng::new(32);
        let qsel = rng.normal_vec(kd, 1.0);
        let idx: Vec<usize> = (0..kd).collect();
        let att: Vec<usize> = (0..20).collect();
        let run = |scalar: bool| {
            force_scalar(scalar);
            let mut page_scores = vec![0.0f32; lay.page_slots];
            let mut z_out = vec![0.0f32; 64];
            let mut out_h = vec![0.0f32; d];
            let mut stats = FusedStats::default();
            let osm = fused_attend(
                &qsel, &idx, &pool, &table, 0, 0, &att, 0.5, &mut page_scores, &mut z_out,
                &mut out_h, &mut stats,
            );
            force_scalar(false);
            (out_h, z_out, osm.m, osm.denom)
        };
        assert_eq!(run(false), run(true), "SIMD on/off must be bit-identical");
    }
}
