//! AQUA knobs and the §5 cost model.
//!
//! `k_ratio` — fraction of projected dimensions retained by the dynamic
//! magnitude selection. `S_ratio` — fraction of trailing principal
//! dimensions statically sliced before caching (AQUA-Memory). The paper's
//! effective ratio is `E_ratio = (1 - S_ratio) · k_ratio`.

/// Resolved AQUA configuration for one engine run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AquaConfig {
    /// Dynamic retention ratio (1.0 = no pruning; the 'B' baseline also
    /// sets an identity projection).
    pub k_ratio: f64,
    /// AQUA-Memory static slice ratio (0.0 = off).
    pub s_ratio: f64,
    /// Use the calibrated projection (false = identity P: exact standard
    /// attention; the baseline rows of every table).
    pub use_projection: bool,
    /// H2O heavy-hitter budget as a fraction of the live context
    /// (1.0 = eviction off).
    pub h2o_ratio: f64,
}

impl Default for AquaConfig {
    fn default() -> Self {
        AquaConfig { k_ratio: 1.0, s_ratio: 0.0, use_projection: true, h2o_ratio: 1.0 }
    }
}

impl AquaConfig {
    pub fn baseline() -> Self {
        AquaConfig { k_ratio: 1.0, s_ratio: 0.0, use_projection: false, h2o_ratio: 1.0 }
    }

    /// Number of dims the *static* memory slice keeps of `d`.
    pub fn mem_dims(&self, d: usize) -> usize {
        (((1.0 - self.s_ratio) * d as f64).round() as usize).clamp(1, d)
    }

    /// Runtime top-k dims: `k_ratio` applied to the *remaining* dims
    /// (paper §8.4: "the k_ratio hyperparameter is applied to this smaller
    /// set of dimensions").
    pub fn k_dims(&self, d: usize) -> usize {
        ((self.k_ratio * self.mem_dims(d) as f64).round() as usize).clamp(1, d)
    }

    /// E_ratio = (1 - S_ratio) · k_ratio.
    pub fn effective_ratio(&self) -> f64 {
        (1.0 - self.s_ratio) * self.k_ratio
    }

    /// The AQUA-Memory keep mask over projected dims (leading principal
    /// dims kept — the projection orders dims by decreasing variance).
    pub fn dim_keep_mask(&self, d: usize) -> Vec<f32> {
        let keep = self.mem_dims(d);
        (0..d).map(|i| if i < keep { 1.0 } else { 0.0 }).collect()
    }

    /// Per-token-slot *resident* KV bytes (f32 K̂ slice + full V, across
    /// all layers) — the AQUA-Memory saving the paper's Table 3 trades
    /// against accuracy. Since the paged KV pool this is no longer a
    /// cost-model projection: it equals `PoolLayout::bytes_per_slot` for
    /// the pool the backend actually allocates
    /// (`kvpool` property-tests the two never drift).
    pub fn kv_bytes_per_slot(&self, d: usize, n_kv: usize, n_layers: usize) -> usize {
        n_layers * n_kv * (self.mem_dims(d) + d) * 4
    }
}

// ---------------------------------------------------------------------------
// §5 cost model
// ---------------------------------------------------------------------------

/// FLOP counts for the unnormalized-score stage at decode step `i+1`
/// (paper §5; multiply-add pairs counted as 2 FLOPs).
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub d_head: usize,
}

impl CostModel {
    /// C_std = (i+1)·d
    pub fn standard_flops(&self, seq: usize) -> u64 {
        2 * (seq as u64) * self.d_head as u64
    }

    /// C_AQUA = d² (projection of q and k: 2·d² MACs) + (i+1)·k
    pub fn aqua_flops(&self, seq: usize, k: usize) -> u64 {
        let d = self.d_head as u64;
        2 * (2 * d * d) + 2 * (seq as u64) * k as u64
    }

    /// The paper's break-even bound: AQUA wins for i+1 > d²/(d−k).
    /// Returns None when k >= d (no savings, never breaks even — §A.4
    /// case 4). NOTE: the paper's bound counts the projection as one d²
    /// term; we expose both the paper bound and our 2·d² implementation
    /// bound so benches can compare.
    pub fn paper_breakeven(&self, k: usize) -> Option<usize> {
        if k >= self.d_head {
            return None;
        }
        let d = self.d_head as f64;
        Some((d * d / (d - k as f64)).ceil() as usize)
    }

    /// Break-even of this implementation's cost model (2 projections).
    pub fn impl_breakeven(&self, k: usize) -> Option<usize> {
        if k >= self.d_head {
            return None;
        }
        let d = self.d_head as f64;
        Some((2.0 * d * d / (d - k as f64)).ceil() as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_resolution() {
        let c = AquaConfig { k_ratio: 0.75, s_ratio: 0.0, ..Default::default() };
        assert_eq!(c.k_dims(32), 24);
        assert_eq!(c.mem_dims(32), 32);
        assert!((c.effective_ratio() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn memory_slice_composition() {
        // paper Table 3: S=0.10, k=0.90 -> E = 0.81
        let c = AquaConfig { k_ratio: 0.9, s_ratio: 0.1, ..Default::default() };
        assert!((c.effective_ratio() - 0.81).abs() < 1e-12);
        let d = 32;
        assert_eq!(c.mem_dims(d), 29);
        assert_eq!(c.k_dims(d), 26);
        let mask = c.dim_keep_mask(d);
        assert_eq!(mask.iter().filter(|&&m| m > 0.5).count(), 29);
        assert!(mask[..29].iter().all(|&m| m == 1.0));
    }

    #[test]
    fn extreme_knobs_clamped() {
        let c = AquaConfig { k_ratio: 0.0, s_ratio: 0.99, ..Default::default() };
        assert!(c.k_dims(32) >= 1);
        assert!(c.mem_dims(32) >= 1);
    }

    #[test]
    fn paper_numerical_example() {
        // §A.4: d=128 -> k=16: 147, k=64: 256, k=112: 1024, k=128: never.
        let m = CostModel { d_head: 128 };
        assert_eq!(m.paper_breakeven(16), Some(147));
        assert_eq!(m.paper_breakeven(64), Some(256));
        assert_eq!(m.paper_breakeven(112), Some(1024));
        assert_eq!(m.paper_breakeven(128), None);
    }

    #[test]
    fn crossover_matches_flop_model() {
        let m = CostModel { d_head: 64 };
        let k = 32;
        let be = m.impl_breakeven(k).unwrap();
        assert!(m.aqua_flops(be + 1, k) < m.standard_flops(be + 1));
        assert!(m.aqua_flops(be.saturating_sub(2), k) >= m.standard_flops(be.saturating_sub(2)));
    }

    #[test]
    fn kv_bytes_scale_with_slice_and_match_pool_layout() {
        let base = AquaConfig::default().kv_bytes_per_slot(32, 2, 4);
        let cfg = AquaConfig { s_ratio: 0.25, ..Default::default() };
        let sliced = cfg.kv_bytes_per_slot(32, 2, 4);
        assert!(sliced < base);
        // the cost model and the pool's actual allocation are one formula
        let layout = crate::kvpool::PoolLayout {
            page_slots: 16,
            key_dims: cfg.mem_dims(32),
            head_dim: 32,
            layers: 4,
            kv_heads: 2,
            kv_quant: crate::kvpool::KvQuant::F32,
        };
        assert_eq!(sliced, layout.bytes_per_slot());
    }
}
