//! Deployment specs: one named model/knob operating point of the fleet.
//!
//! A spec is declared either as a CLI kv-spec (`--model
//! name=fast,backend=native,k=0.25,threads=2`) or as one entry of the
//! fleet-config JSON (`aqua serve --fleet fleet.json`, `POST /models`),
//! and resolves into the `(BackendSpec, EngineConfig)` pair a
//! [`super::Deployment`] spins up. The JSON and kv forms round-trip
//! through [`DeploymentSpec::to_json`] so `GET /models` reports exactly
//! what was deployed.

use anyhow::{bail, Context, Result};

use crate::aqua::policy::AquaConfig;
use crate::coordinator::EngineConfig;
use crate::runtime::backend::BackendSpec;
use crate::util::json::Json;

/// Default admission bound: in-flight requests beyond this are shed (429).
pub const DEFAULT_MAX_INFLIGHT: usize = 32;

/// Everything needed to launch one named deployment.
#[derive(Debug, Clone, PartialEq)]
pub struct DeploymentSpec {
    /// Registry key and URL path segment (`/models/{name}`).
    pub name: String,
    /// Backend kind: `auto | native | sharded | pjrt`.
    pub backend: String,
    /// Model config name (native preset / artifact key).
    pub model: String,
    /// Weight + sampler seed (native backends; determinism knob).
    pub seed: u64,
    /// Worker threads (sharded backend only).
    pub threads: usize,
    /// Engine batch lanes.
    pub batch: usize,
    /// Admission bound: submits beyond this many in-flight requests shed.
    pub max_inflight: usize,
    /// KV pool budget in MiB; 0.0 = unlimited. Submits whose worst-case
    /// page growth the pool cannot cover shed with a distinct
    /// memory-pressure 429 (see `registry::Deployment`).
    pub kv_budget_mb: f64,
    /// Page-granular prefix sharing: one prefill's KV pages serve every
    /// lane whose prompt shares the prefix (kv key `prefix`, JSON
    /// `prefix_cache`). Greedy outputs stay bit-identical to the
    /// sharing-disabled path; the engine declines to share when H2O
    /// eviction is active. Off by default.
    pub prefix_cache: bool,
    /// Prefix-index capacity in registered page chains (kv key
    /// `prefix_pages`, JSON `prefix_cache_pages`; 0 = unlimited).
    pub prefix_cache_pages: usize,
    /// Resident-KV payload element type: `"f32"` (default) or `"int8"`
    /// (kv/JSON key `kv_quant`). Int8 stores truncated keys and values
    /// as symmetric int8 with per-page/(layer,head) scales and routes
    /// decode through the fused dequantizing kernel; f32 stays
    /// bit-identical to the pre-quantization pool.
    pub kv_quant: String,
    /// Scheduler budget: prefill tokens per engine pass (kv key
    /// `prefill_tokens`; 0 = unlimited). Whole per-lane chunks, so
    /// outputs stay bit-identical to the uncapped path.
    pub max_batch_prefill_tokens: usize,
    /// Scheduler budget: Σ worst-case tokens (prompt + max_new) across
    /// the running batch (kv key `total_tokens`; 0 = unlimited).
    pub max_batch_total_tokens: usize,
    /// Queue pressure threshold (`waiting / served`) above which a
    /// budget-blocked queue head may be overtaken, boundedly, by
    /// admissible smaller requests (kv key `wsr`).
    pub waiting_served_ratio: f64,
    /// Chunked-prefill interleaving (the token-budget continuous
    /// scheduler). On by default; off reproduces the legacy
    /// prefill-priority FIFO engine exactly.
    pub interleave: bool,
    /// Supervisor restart budget: rebuilds allowed after engine crashes
    /// (kv key `restart`; 0 = fail fast, first crash flips the
    /// deployment to Failed).
    pub restart: u32,
    /// Initial supervisor backoff before a rebuild, milliseconds; doubles
    /// per consecutive crash, capped at 5 s (kv key `restart_backoff_ms`).
    pub restart_backoff_ms: u64,
    /// Default per-request deadline in milliseconds, measured from
    /// enqueue (kv key `deadline_ms`; 0 = none). Requests may carry their
    /// own `deadline_ms`, which wins over this default.
    pub deadline_ms: u64,
    /// Consecutive failing engine passes tolerated before the engine is
    /// declared failed (kv key `max_step_failures`; clamped ≥ 1).
    pub max_step_failures: usize,
    /// Flight-recorder mode: `off | errors | sampled:N | full` (kv/JSON
    /// key `trace`). Validated via `TraceMode::parse`; the recorder is an
    /// `Arc` shared across engine incarnations (like metrics), surfaced
    /// at `GET /trace` / `GET /trace/postmortem`.
    pub trace: String,
    /// Self-speculative decoding draft depth (kv/JSON key `speculate`;
    /// 0 = off, byte-identical to the plain decode path). Lossless —
    /// committed tokens are always the exact path's argmax; the engine
    /// falls back to plain decoding under H2O or non-greedy sampling.
    pub speculate: usize,
    /// AQUA operating point for every request this deployment serves.
    pub aqua: AquaConfig,
}

impl Default for DeploymentSpec {
    fn default() -> Self {
        DeploymentSpec {
            name: "default".to_string(),
            backend: "auto".to_string(),
            model: "llama-analog".to_string(),
            seed: 0,
            threads: 4,
            batch: 4,
            max_inflight: DEFAULT_MAX_INFLIGHT,
            kv_budget_mb: 0.0,
            prefix_cache: false,
            prefix_cache_pages: 0,
            kv_quant: "f32".to_string(),
            max_batch_prefill_tokens: 0,
            max_batch_total_tokens: 0,
            waiting_served_ratio: 1.2,
            interleave: true,
            restart: 0,
            restart_backoff_ms: 50,
            deadline_ms: 0,
            max_step_failures: 3,
            trace: "off".to_string(),
            speculate: 0,
            aqua: AquaConfig::default(),
        }
    }
}

impl DeploymentSpec {
    /// Parse a CLI kv-spec: comma-separated `key=value` pairs. Keys:
    /// `name` (required), `backend`, `model`, `seed`, `threads`, `batch`,
    /// `queue` (max in-flight), `kv_mb`, `prefix` (0/1 prefix sharing),
    /// `prefix_pages`, `kv_quant` (f32|int8), `prefill_tokens`,
    /// `total_tokens`, `wsr`,
    /// `interleave` (0/1), `restart`, `restart_backoff_ms`,
    /// `deadline_ms`, `max_step_failures`, `trace`
    /// (off|errors|sampled:N|full), `speculate` (draft depth, 0 = off),
    /// `k`/`k_ratio`, `s`/`s_ratio`, `h2o`/`h2o_ratio`, `proj` (0/1).
    ///
    /// Note the comma is the pair separator, so fault-backend parameters
    /// inside a kv-spec use `;`: `backend=fault:native;err_every=50`.
    pub fn parse_kv(s: &str) -> Result<DeploymentSpec> {
        let mut spec = DeploymentSpec { name: String::new(), ..Default::default() };
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) =
                part.split_once('=').with_context(|| format!("expected key=value in '{part}'"))?;
            match k {
                "name" => spec.name = v.to_string(),
                "backend" => spec.backend = v.to_string(),
                "model" => spec.model = v.to_string(),
                "seed" => spec.seed = v.parse().with_context(|| format!("bad seed '{v}'"))?,
                "threads" => {
                    spec.threads = v.parse().with_context(|| format!("bad threads '{v}'"))?
                }
                "batch" => spec.batch = v.parse().with_context(|| format!("bad batch '{v}'"))?,
                "queue" => {
                    spec.max_inflight = v.parse().with_context(|| format!("bad queue '{v}'"))?
                }
                "kv_mb" | "kv_budget_mb" => {
                    spec.kv_budget_mb =
                        v.parse().with_context(|| format!("bad kv budget '{v}'"))?
                }
                "prefix" | "prefix_cache" => {
                    spec.prefix_cache = match v {
                        "1" | "true" | "yes" | "on" => true,
                        "0" | "false" | "no" | "off" => false,
                        other => bail!("bad prefix toggle '{other}' (expected 0/1)"),
                    }
                }
                "prefix_pages" | "prefix_cache_pages" => {
                    spec.prefix_cache_pages =
                        v.parse().with_context(|| format!("bad prefix_pages '{v}'"))?
                }
                "kv_quant" => spec.kv_quant = v.to_string(),
                "prefill_tokens" | "max_batch_prefill_tokens" => {
                    spec.max_batch_prefill_tokens =
                        v.parse().with_context(|| format!("bad prefill_tokens '{v}'"))?
                }
                "total_tokens" | "max_batch_total_tokens" => {
                    spec.max_batch_total_tokens =
                        v.parse().with_context(|| format!("bad total_tokens '{v}'"))?
                }
                "wsr" | "waiting_served_ratio" => {
                    spec.waiting_served_ratio =
                        v.parse().with_context(|| format!("bad waiting_served_ratio '{v}'"))?
                }
                "interleave" => {
                    spec.interleave = match v {
                        "1" | "true" | "yes" | "on" => true,
                        "0" | "false" | "no" | "off" => false,
                        other => bail!("bad interleave toggle '{other}' (expected 0/1)"),
                    }
                }
                "restart" | "restarts" => {
                    spec.restart = v.parse().with_context(|| format!("bad restart '{v}'"))?
                }
                "restart_backoff_ms" => {
                    spec.restart_backoff_ms =
                        v.parse().with_context(|| format!("bad restart_backoff_ms '{v}'"))?
                }
                "deadline_ms" => {
                    spec.deadline_ms =
                        v.parse().with_context(|| format!("bad deadline_ms '{v}'"))?
                }
                "max_step_failures" => {
                    spec.max_step_failures =
                        v.parse().with_context(|| format!("bad max_step_failures '{v}'"))?
                }
                "trace" => spec.trace = v.to_string(),
                "speculate" => {
                    spec.speculate = v.parse().with_context(|| format!("bad speculate '{v}'"))?
                }
                "k" | "k_ratio" => {
                    spec.aqua.k_ratio = v.parse().with_context(|| format!("bad k_ratio '{v}'"))?
                }
                "s" | "s_ratio" => {
                    spec.aqua.s_ratio = v.parse().with_context(|| format!("bad s_ratio '{v}'"))?
                }
                "h2o" | "h2o_ratio" => {
                    spec.aqua.h2o_ratio =
                        v.parse().with_context(|| format!("bad h2o_ratio '{v}'"))?
                }
                "proj" => spec.aqua.use_projection = matches!(v, "1" | "true" | "yes"),
                other => bail!("unknown deployment key '{other}' in '{s}'"),
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Parse one fleet-config JSON entry (field names match `to_json`).
    pub fn from_json(j: &Json) -> Result<DeploymentSpec> {
        let mut spec =
            DeploymentSpec { name: j.req_str("name")?.to_string(), ..Default::default() };
        if let Some(v) = j.get("backend").as_str() {
            spec.backend = v.to_string();
        }
        if let Some(v) = j.get("model").as_str() {
            spec.model = v.to_string();
        }
        if let Some(v) = j.get("seed").as_i64() {
            spec.seed = v.max(0) as u64;
        }
        if let Some(v) = j.get("threads").as_i64() {
            spec.threads = v.max(0) as usize;
        }
        if let Some(v) = j.get("batch").as_i64() {
            spec.batch = v.max(0) as usize;
        }
        if let Some(v) = j.get("max_inflight").as_i64() {
            spec.max_inflight = v.max(0) as usize;
        }
        if let Some(v) = j.get("kv_budget_mb").as_f64() {
            spec.kv_budget_mb = v;
        }
        if let Some(v) = j.get("prefix_cache").as_bool() {
            spec.prefix_cache = v;
        }
        if let Some(v) = j.get("prefix_cache_pages").as_i64() {
            spec.prefix_cache_pages = v.max(0) as usize;
        }
        if let Some(v) = j.get("kv_quant").as_str() {
            spec.kv_quant = v.to_string();
        }
        if let Some(v) = j.get("max_batch_prefill_tokens").as_i64() {
            spec.max_batch_prefill_tokens = v.max(0) as usize;
        }
        if let Some(v) = j.get("max_batch_total_tokens").as_i64() {
            spec.max_batch_total_tokens = v.max(0) as usize;
        }
        if let Some(v) = j.get("waiting_served_ratio").as_f64() {
            spec.waiting_served_ratio = v;
        }
        if let Some(v) = j.get("interleave").as_bool() {
            spec.interleave = v;
        }
        if let Some(v) = j.get("restart").as_i64() {
            spec.restart = v.max(0) as u32;
        }
        if let Some(v) = j.get("restart_backoff_ms").as_i64() {
            spec.restart_backoff_ms = v.max(0) as u64;
        }
        if let Some(v) = j.get("deadline_ms").as_i64() {
            spec.deadline_ms = v.max(0) as u64;
        }
        if let Some(v) = j.get("max_step_failures").as_i64() {
            spec.max_step_failures = v.max(0) as usize;
        }
        if let Some(v) = j.get("trace").as_str() {
            spec.trace = v.to_string();
        }
        if let Some(v) = j.get("speculate").as_i64() {
            spec.speculate = v.max(0) as usize;
        }
        if let Some(v) = j.get("k_ratio").as_f64() {
            spec.aqua.k_ratio = v;
        }
        if let Some(v) = j.get("s_ratio").as_f64() {
            spec.aqua.s_ratio = v;
        }
        if let Some(v) = j.get("h2o_ratio").as_f64() {
            spec.aqua.h2o_ratio = v;
        }
        if let Some(v) = j.get("use_projection").as_bool() {
            spec.aqua.use_projection = v;
        }
        spec.validate()?;
        Ok(spec)
    }

    /// The round-trippable JSON form (`GET /models`, fleet configs).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("backend", Json::Str(self.backend.clone())),
            ("model", Json::Str(self.model.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("threads", Json::Num(self.threads as f64)),
            ("batch", Json::Num(self.batch as f64)),
            ("max_inflight", Json::Num(self.max_inflight as f64)),
            ("kv_budget_mb", Json::Num(self.kv_budget_mb)),
            ("prefix_cache", Json::Bool(self.prefix_cache)),
            ("prefix_cache_pages", Json::Num(self.prefix_cache_pages as f64)),
            ("kv_quant", Json::Str(self.kv_quant.clone())),
            ("max_batch_prefill_tokens", Json::Num(self.max_batch_prefill_tokens as f64)),
            ("max_batch_total_tokens", Json::Num(self.max_batch_total_tokens as f64)),
            ("waiting_served_ratio", Json::Num(self.waiting_served_ratio)),
            ("interleave", Json::Bool(self.interleave)),
            ("restart", Json::Num(self.restart as f64)),
            ("restart_backoff_ms", Json::Num(self.restart_backoff_ms as f64)),
            ("deadline_ms", Json::Num(self.deadline_ms as f64)),
            ("max_step_failures", Json::Num(self.max_step_failures as f64)),
            ("trace", Json::Str(self.trace.clone())),
            ("speculate", Json::Num(self.speculate as f64)),
            ("k_ratio", Json::Num(self.aqua.k_ratio)),
            ("s_ratio", Json::Num(self.aqua.s_ratio)),
            ("h2o_ratio", Json::Num(self.aqua.h2o_ratio)),
            ("use_projection", Json::Bool(self.aqua.use_projection)),
        ])
    }

    /// Invariant check. Called by the parsers (fail fast with parse
    /// context) and again by `Deployment::launch`, so hand-built spec
    /// literals (e.g. the CLI's classic single-model path) cannot bypass
    /// it.
    pub(crate) fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("deployment spec needs a non-empty 'name'");
        }
        if !self.name.chars().all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')) {
            bail!("deployment name '{}' must be [A-Za-z0-9._-] (it is a URL segment)", self.name);
        }
        // a `fault:` wrapper is validated down to its inner kind here;
        // the fault parameters themselves are checked by FaultPlan::parse
        // when the backend spec is built
        let base = match self.backend.strip_prefix("fault:") {
            Some(rest) => rest.split([',', ';']).next().unwrap_or(rest),
            None => self.backend.as_str(),
        };
        if !matches!(base, "auto" | "native" | "sharded" | "pjrt") {
            bail!(
                "unknown backend '{}' (expected auto|native|sharded|pjrt, \
                 optionally wrapped as fault:<inner>)",
                self.backend
            );
        }
        if self.batch == 0 {
            bail!("deployment '{}': batch must be >= 1", self.name);
        }
        if self.threads == 0 {
            bail!("deployment '{}': threads must be >= 1", self.name);
        }
        if self.max_inflight == 0 {
            bail!("deployment '{}': queue/max_inflight must be >= 1", self.name);
        }
        if !self.kv_budget_mb.is_finite() || self.kv_budget_mb < 0.0 {
            bail!("deployment '{}': kv_budget_mb {} must be >= 0", self.name, self.kv_budget_mb);
        }
        if !self.waiting_served_ratio.is_finite() || self.waiting_served_ratio < 0.0 {
            bail!(
                "deployment '{}': waiting_served_ratio {} must be >= 0",
                self.name,
                self.waiting_served_ratio
            );
        }
        for (label, v) in [
            ("k_ratio", self.aqua.k_ratio),
            ("s_ratio", self.aqua.s_ratio),
            ("h2o_ratio", self.aqua.h2o_ratio),
        ] {
            if !(0.0..=1.0).contains(&v) {
                bail!("deployment '{}': {label} {v} outside [0, 1]", self.name);
            }
        }
        crate::trace::TraceMode::parse(&self.trace)
            .with_context(|| format!("deployment '{}'", self.name))?;
        crate::kvpool::KvQuant::parse(&self.kv_quant)
            .with_context(|| format!("deployment '{}'", self.name))?;
        Ok(())
    }

    /// The parsed flight-recorder mode (validate() guarantees this parses).
    pub fn trace_mode(&self) -> crate::trace::TraceMode {
        crate::trace::TraceMode::parse(&self.trace).unwrap_or_default()
    }

    /// Resolve into a backend spec. Native/sharded weights are built here,
    /// on the caller's thread (they are `Send`); the PJRT path loads its
    /// artifacts here and fails fast if they are missing.
    pub fn backend_spec(&self, arts_dir: &str) -> Result<BackendSpec> {
        BackendSpec::from_kind(&self.backend, &self.model, self.seed, self.threads, arts_dir)
            .with_context(|| format!("deployment '{}'", self.name))
    }

    /// The engine configuration this spec pins.
    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig {
            batch: self.batch,
            aqua: self.aqua,
            seed: self.seed,
            kv_budget_mb: self.kv_budget_mb,
            prefix_cache: self.prefix_cache,
            prefix_cache_pages: self.prefix_cache_pages,
            max_batch_prefill_tokens: self.max_batch_prefill_tokens,
            max_batch_total_tokens: self.max_batch_total_tokens,
            waiting_served_ratio: self.waiting_served_ratio,
            interleave: self.interleave,
            max_consecutive_step_failures: self.max_step_failures.max(1),
            trace: self.trace_mode(),
            speculate: self.speculate,
            kv_quant: crate::kvpool::KvQuant::parse(&self.kv_quant).unwrap_or_default(),
            ..Default::default()
        }
    }

    /// The supervisor restart policy this spec pins.
    pub fn restart_policy(&self) -> crate::coordinator::RestartPolicy {
        crate::coordinator::RestartPolicy {
            max_restarts: self.restart,
            backoff: std::time::Duration::from_millis(self.restart_backoff_ms.max(1)),
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_roundtrip_through_json() {
        let spec = DeploymentSpec::parse_kv(
            "name=fast,backend=sharded,k=0.25,threads=2,batch=8,queue=5,kv_mb=2.5,prefix=1,\
             prefix_pages=64,kv_quant=int8,prefill_tokens=96,total_tokens=512,wsr=1.5,\
             interleave=0",
        )
        .unwrap();
        assert_eq!(spec.name, "fast");
        assert_eq!(spec.backend, "sharded");
        assert_eq!(spec.threads, 2);
        assert_eq!(spec.batch, 8);
        assert_eq!(spec.max_inflight, 5);
        assert!((spec.kv_budget_mb - 2.5).abs() < 1e-12);
        assert!(spec.prefix_cache);
        assert_eq!(spec.prefix_cache_pages, 64);
        assert_eq!(spec.kv_quant, "int8");
        assert_eq!(spec.max_batch_prefill_tokens, 96);
        assert_eq!(spec.max_batch_total_tokens, 512);
        assert!((spec.waiting_served_ratio - 1.5).abs() < 1e-12);
        assert!(!spec.interleave);
        assert!((spec.aqua.k_ratio - 0.25).abs() < 1e-12);
        let back = DeploymentSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn scheduler_knobs_default_and_reach_engine_config() {
        // interleave on by default, budgets unlimited
        let d = DeploymentSpec::default();
        assert!(d.interleave);
        assert_eq!(d.max_batch_prefill_tokens, 0);
        assert_eq!(d.max_batch_total_tokens, 0);
        assert!((d.waiting_served_ratio - 1.2).abs() < 1e-12);
        // JSON surface, and the knobs reach the engine config
        let j = Json::parse(
            r#"{"name": "a", "max_batch_prefill_tokens": 48, "max_batch_total_tokens": 400,
                "waiting_served_ratio": 2.0, "interleave": false}"#,
        )
        .unwrap();
        let spec = DeploymentSpec::from_json(&j).unwrap();
        let ecfg = spec.engine_config();
        assert_eq!(ecfg.max_batch_prefill_tokens, 48);
        assert_eq!(ecfg.max_batch_total_tokens, 400);
        assert!((ecfg.waiting_served_ratio - 2.0).abs() < 1e-12);
        assert!(!ecfg.interleave);
        // bad values rejected on every surface
        assert!(DeploymentSpec::parse_kv("name=a,wsr=-1").is_err());
        assert!(DeploymentSpec::parse_kv("name=a,interleave=maybe").is_err());
        assert!(DeploymentSpec::parse_kv("name=a,prefill_tokens=x").is_err());
    }

    #[test]
    fn prefix_cache_knob_defaults_and_parses() {
        // default off on every surface
        assert!(!DeploymentSpec::default().prefix_cache);
        let spec = DeploymentSpec::parse_kv("name=a").unwrap();
        assert!(!spec.prefix_cache);
        assert_eq!(spec.prefix_cache_pages, 0);
        // kv surface
        let on = DeploymentSpec::parse_kv("name=a,prefix=on").unwrap();
        assert!(on.prefix_cache);
        assert!(!DeploymentSpec::parse_kv("name=a,prefix=0").unwrap().prefix_cache);
        assert!(DeploymentSpec::parse_kv("name=a,prefix=maybe").is_err());
        // JSON surface, and the knob reaches the engine config
        let j = Json::parse(r#"{"name": "a", "prefix_cache": true, "prefix_cache_pages": 9}"#)
            .unwrap();
        let spec = DeploymentSpec::from_json(&j).unwrap();
        assert!(spec.prefix_cache);
        assert_eq!(spec.prefix_cache_pages, 9);
        let ecfg = spec.engine_config();
        assert!(ecfg.prefix_cache);
        assert_eq!(ecfg.prefix_cache_pages, 9);
    }

    #[test]
    fn fault_and_lifecycle_knobs_parse_and_roundtrip() {
        // fault-wrapped backend accepted on the kv surface, with `;`
        // separating the fault params from the inner kind
        let spec = DeploymentSpec::parse_kv(
            "name=chaos,backend=fault:native;err_every=50,restart=2,restart_backoff_ms=10,\
             deadline_ms=750,max_step_failures=5",
        )
        .unwrap();
        assert_eq!(spec.backend, "fault:native;err_every=50");
        assert_eq!(spec.restart, 2);
        assert_eq!(spec.restart_backoff_ms, 10);
        assert_eq!(spec.deadline_ms, 750);
        assert_eq!(spec.max_step_failures, 5);
        let back = DeploymentSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // the knobs reach the engine config + restart policy
        assert_eq!(spec.engine_config().max_consecutive_step_failures, 5);
        let pol = spec.restart_policy();
        assert_eq!(pol.max_restarts, 2);
        assert_eq!(pol.backoff, std::time::Duration::from_millis(10));
        // the wrapped spec actually builds
        assert_eq!(spec.backend_spec("no-such-dir").unwrap().name(), "fault");
        // but a fault wrapper around an unknown inner kind is rejected
        assert!(DeploymentSpec::parse_kv("name=a,backend=fault:gpu").is_err());
        assert!(DeploymentSpec::parse_kv("name=a,backend=fault:fault:native").is_err());
        // defaults: no restarts, no deadline, 3-strikes escalation
        let d = DeploymentSpec::default();
        assert_eq!(d.restart, 0);
        assert_eq!(d.deadline_ms, 0);
        assert_eq!(d.max_step_failures, 3);
    }

    #[test]
    fn trace_knob_parses_on_every_surface() {
        use crate::trace::TraceMode;
        assert_eq!(DeploymentSpec::default().trace, "off");
        let spec = DeploymentSpec::parse_kv("name=a,trace=sampled:8").unwrap();
        assert_eq!(spec.trace, "sampled:8");
        assert_eq!(spec.trace_mode(), TraceMode::Sampled(8));
        let back = DeploymentSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // the knob reaches the engine config; bad modes rejected on both
        // surfaces
        assert_eq!(spec.engine_config().trace, TraceMode::Sampled(8));
        assert!(DeploymentSpec::parse_kv("name=a,trace=loud").is_err());
        assert!(DeploymentSpec::parse_kv("name=a,trace=sampled:0").is_err());
        let j = Json::parse(r#"{"name": "a", "trace": "errors"}"#).unwrap();
        assert_eq!(DeploymentSpec::from_json(&j).unwrap().trace_mode(), TraceMode::Errors);
        let bad = Json::parse(r#"{"name": "a", "trace": "shouty"}"#).unwrap();
        assert!(DeploymentSpec::from_json(&bad).is_err());
    }

    #[test]
    fn speculate_knob_parses_on_every_surface() {
        assert_eq!(DeploymentSpec::default().speculate, 0, "off by default");
        let spec = DeploymentSpec::parse_kv("name=a,speculate=4,k=0.25").unwrap();
        assert_eq!(spec.speculate, 4);
        let back = DeploymentSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // the knob reaches the engine config
        assert_eq!(spec.engine_config().speculate, 4);
        let j = Json::parse(r#"{"name": "a", "speculate": 3}"#).unwrap();
        assert_eq!(DeploymentSpec::from_json(&j).unwrap().speculate, 3);
        assert!(DeploymentSpec::parse_kv("name=a,speculate=many").is_err());
    }

    #[test]
    fn kv_quant_knob_parses_on_every_surface() {
        use crate::kvpool::KvQuant;
        assert_eq!(DeploymentSpec::default().kv_quant, "f32", "f32 by default");
        assert_eq!(DeploymentSpec::default().engine_config().kv_quant, KvQuant::F32);
        let spec = DeploymentSpec::parse_kv("name=a,kv_quant=int8").unwrap();
        assert_eq!(spec.kv_quant, "int8");
        let back = DeploymentSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // the knob reaches the engine config; bad spellings rejected on
        // both surfaces
        assert_eq!(spec.engine_config().kv_quant, KvQuant::Int8);
        assert!(DeploymentSpec::parse_kv("name=a,kv_quant=fp8").is_err());
        let j = Json::parse(r#"{"name": "a", "kv_quant": "int8"}"#).unwrap();
        assert_eq!(DeploymentSpec::from_json(&j).unwrap().kv_quant, "int8");
        let bad = Json::parse(r#"{"name": "a", "kv_quant": "int4"}"#).unwrap();
        assert!(DeploymentSpec::from_json(&bad).is_err());
    }

    #[test]
    fn json_defaults_fill_in() {
        let j = Json::parse(r#"{"name": "a", "k_ratio": 0.5}"#).unwrap();
        let spec = DeploymentSpec::from_json(&j).unwrap();
        assert_eq!(spec.name, "a");
        assert_eq!(spec.backend, "auto");
        assert_eq!(spec.batch, 4);
        assert_eq!(spec.max_inflight, DEFAULT_MAX_INFLIGHT);
        assert!((spec.aqua.k_ratio - 0.5).abs() < 1e-12);
        assert!((spec.aqua.h2o_ratio - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(DeploymentSpec::parse_kv("backend=native").is_err(), "name required");
        assert!(DeploymentSpec::parse_kv("name=a,backend=gpu").is_err(), "unknown backend");
        assert!(DeploymentSpec::parse_kv("name=a,k=1.5").is_err(), "ratio out of range");
        assert!(DeploymentSpec::parse_kv("name=a,batch=0").is_err(), "zero batch");
        assert!(DeploymentSpec::parse_kv("name=a,queue=0").is_err(), "zero queue");
        assert!(DeploymentSpec::parse_kv("name=a/b").is_err(), "name not URL-safe");
        assert!(DeploymentSpec::parse_kv("name=a,wat=1").is_err(), "unknown key");
        assert!(DeploymentSpec::parse_kv("name=a,kv_mb=-1").is_err(), "negative kv budget");
        assert!(DeploymentSpec::parse_kv("name=a,k").is_err(), "bare key");
        assert!(DeploymentSpec::from_json(&Json::parse(r#"{"backend":"native"}"#).unwrap())
            .is_err());
    }

    #[test]
    fn spec_builds_native_backend_and_engine_config() {
        let spec = DeploymentSpec::parse_kv("name=t,backend=native,seed=9,k=0.5,batch=2").unwrap();
        let bspec = spec.backend_spec("no-such-dir").unwrap();
        assert_eq!(bspec.name(), "native");
        let ecfg = spec.engine_config();
        assert_eq!(ecfg.batch, 2);
        assert_eq!(ecfg.seed, 9);
        assert!((ecfg.aqua.k_ratio - 0.5).abs() < 1e-12);
    }
}
