//! One live deployment: an engine thread, a result pump with TTL sweeping,
//! and a bounded admission gate.
//!
//! The engine is `!Send`-safe by construction (the backend is built on the
//! engine's own thread from a `Send` recipe, exactly like the pre-registry
//! server did), so a deployment owns only channels, counters, and join
//! handles — all of it shareable behind an `Arc` across HTTP workers.
//!
//! Two serving bugs of the single-engine server are fixed here:
//!
//! * **Result leak** — completed results whose client disconnected (or hit
//!   its deadline) used to sit in the shared map forever. The pump now
//!   timestamps every entry and sweeps orphans older than the TTL.
//! * **Unbounded admission** — the engine channel accepted arbitrarily
//!   many requests under open-loop overload. Submits now reserve one of
//!   `max_inflight` slots or shed (HTTP 429), with queue-depth/shed
//!   counters surfaced through `/metrics`.
//!
//! With the paged KV pool, admission is also **memory-aware**: a
//! deployment with `kv_budget_mb > 0` sizes its engine's page pool from
//! the budget, and every submit reserves its worst-case page growth
//! (`ceil((prompt + max_new) / page_slots)`) up front. When the pool
//! cannot cover it the request sheds with [`ShedReason::KvMemory`] — a
//! *distinct* 429 from the `max_inflight` capacity shed — instead of the
//! backend ever stalling mid-decode or over-allocating. Reservations are
//! conservative (H2O eviction returns pages early), so a reservation that
//! fits can never fail at the pool.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::spec::DeploymentSpec;
use crate::coordinator::engine::{Engine, EngineCmd, EngineHandle, EngineStatus, Health};
use crate::coordinator::metrics::Snapshot;
use crate::coordinator::{GenRequest, GenResult};
use crate::kvpool::budget_pages;
use crate::trace::TraceRecorder;

/// Default orphan TTL: results not picked up within this window are swept
/// (the HTTP worker's deadline is shorter, so a live client never loses a
/// result to the sweep).
pub const RESULT_TTL: Duration = Duration::from_secs(180);

/// How often the pump sweeps when no results are arriving.
const SWEEP_TICK: Duration = Duration::from_millis(250);

/// Completed results waiting for pickup, timestamped for the TTL sweep.
#[derive(Default)]
pub struct ResultStore {
    inner: Mutex<HashMap<u64, (GenResult, Instant)>>,
}

impl ResultStore {
    /// Poison-tolerant lock: results must survive a panicking HTTP worker
    /// — the map is plain data, valid regardless of where the holder died
    /// (see the same pattern on `Metrics`).
    fn locked(&self) -> MutexGuard<'_, HashMap<u64, (GenResult, Instant)>> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn insert(&self, res: GenResult) {
        self.locked().insert(res.id, (res, Instant::now()));
    }

    /// Remove and return a delivered result (the normal pickup path — the
    /// entry never outlives its client).
    pub fn take(&self, id: u64) -> Option<GenResult> {
        self.locked().remove(&id).map(|(r, _)| r)
    }

    /// Evict entries older than `ttl`; returns how many were dropped.
    pub fn sweep(&self, ttl: Duration) -> usize {
        let mut g = self.locked();
        let before = g.len();
        let now = Instant::now();
        g.retain(|_, (_, t)| now.duration_since(*t) <= ttl);
        before - g.len()
    }

    pub fn len(&self) -> usize {
        self.locked().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Why a submit was shed (distinct HTTP statuses/bodies and `/metrics`
/// counters, so clients can tell retryable from never-admittable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// `max_inflight` requests already in flight (retryable; HTTP 429).
    Capacity,
    /// Transient memory pressure: in-flight reservations leave too few KV
    /// pages *right now* — pages free as occupants finish (retryable;
    /// HTTP 429).
    KvMemory,
    /// Permanent at this budget: the request's worst-case KV growth alone
    /// exceeds the whole `kv_budget_mb` page budget — a retry can never
    /// succeed (HTTP 413).
    OverBudget,
    /// The engine is not healthy (crashed and restarting, or failed for
    /// good). Retryable iff a restart budget remains (HTTP 503).
    Unhealthy,
}

/// Admission outcome for one submit attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Accepted,
    /// Request shed (HTTP 429); the reason picks the 429 body and counter.
    Shed(ShedReason),
}

/// Point-in-time admission counters for `/metrics`.
#[derive(Debug, Clone, Copy, Default)]
pub struct AdmissionStats {
    /// Requests admitted but not yet completed by the engine.
    pub queue_depth: u64,
    /// Total admitted since launch.
    pub submitted: u64,
    /// Total shed at admission since launch (capacity + memory).
    pub shed: u64,
    /// Sheds due to the `max_inflight` bound.
    pub shed_capacity: u64,
    /// Sheds due to KV memory pressure (`kv_budget_mb`).
    pub shed_memory: u64,
    /// Sheds because the engine was unhealthy/failed at submit time.
    pub shed_unhealthy: u64,
    /// Engine rebuilds the supervisor performed since launch.
    pub engine_restarts: u64,
    /// KV pages currently reserved by in-flight requests (worst case).
    pub kv_reserved_pages: u64,
    /// Page budget (`0` = unlimited).
    pub kv_pages_total: u64,
    /// Orphaned results evicted by the TTL sweep since launch.
    pub swept_results: u64,
}

/// A running engine serving one [`DeploymentSpec`].
pub struct Deployment {
    pub spec: DeploymentSpec,
    /// Resolved backend kind ("native", "sharded", "pjrt") — `spec.backend`
    /// may have been "auto".
    backend_kind: &'static str,
    /// KV capacity of the deployed model (admission-side prompt clamping).
    max_seq: usize,
    cmd_tx: mpsc::Sender<EngineCmd>,
    /// Live engine health + restart counters, published by the supervisor
    /// (`GET /models`, `/healthz`, and the admission gate read this).
    status: Arc<EngineStatus>,
    /// Flight recorder, shared across engine incarnations like `Metrics`
    /// (`GET /trace`, `GET /trace/postmortem`).
    trace: Arc<TraceRecorder>,
    results: Arc<ResultStore>,
    next_id: AtomicU64,
    in_flight: Arc<AtomicU64>,
    /// Page budget from `kv_budget_mb` (None = unlimited). Mirrors the
    /// engine's pool cap exactly (same `budget_pages` arithmetic).
    kv_pages_total: Option<u64>,
    /// Pool geometry (worst-case reservation sizing — the same
    /// `EngineConfig::pool_layout` the engine's pool derives from).
    kv_layout: crate::kvpool::PoolLayout,
    /// Worst-case pages reserved by in-flight requests.
    kv_reserved: Arc<AtomicU64>,
    /// Per-request reservation sizes, released by the pump on completion.
    kv_reservations: Arc<Mutex<HashMap<u64, u64>>>,
    /// Submit calls currently between their draining-check and their
    /// channel send. `shutdown` waits for this to reach zero after
    /// setting `draining`, so an accepted request's `Submit` is always
    /// enqueued before the `Shutdown` command (mpsc delivers
    /// happens-before-ordered sends in order — nothing admitted is ever
    /// silently dropped by the drain).
    submitting: AtomicU64,
    submitted: AtomicU64,
    shed_capacity: AtomicU64,
    shed_memory: AtomicU64,
    shed_unhealthy: AtomicU64,
    swept: Arc<AtomicU64>,
    ttl_ms: Arc<AtomicU64>,
    draining: AtomicBool,
    engine_join: Mutex<Option<std::thread::JoinHandle<()>>>,
    pump_join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Deployment {
    /// Spin up the engine thread + result pump for `spec`. Backend weights
    /// and artifacts resolve here (fail fast); the backend itself is
    /// constructed on the engine thread from the `Send` recipe.
    pub fn launch(spec: DeploymentSpec, arts_dir: &str) -> Result<Deployment> {
        spec.validate()?;
        let bspec = spec.backend_spec(arts_dir)?;
        let backend_kind = bspec.name();
        let mc = bspec.model_config();
        let max_seq = mc.max_seq;
        let ecfg = spec.engine_config();
        // Derive the page geometry through the *same* EngineConfig helper
        // the engine's pool cap uses, so the admission gate and the pool
        // can never disagree on page arithmetic.
        let kv_layout = ecfg.pool_layout(mc);
        let kv_pages_total = budget_pages(ecfg.kv_budget_mb, &kv_layout).map(|p| p as u64);
        if kv_pages_total == Some(0) {
            // would shed 100% of traffic while /metrics shows the same
            // kv_pages_total = 0 an *unlimited* deployment reports —
            // surface the misconfiguration at launch instead
            bail!(
                "deployment '{}': kv_budget_mb {} buys zero {}-byte KV pages",
                spec.name,
                spec.kv_budget_mb,
                kv_layout.page_bytes()
            );
        }
        let recipe = bspec.recipe();
        let status = Arc::new(EngineStatus::default());
        let trace = Arc::new(TraceRecorder::new(spec.trace_mode()));
        // Supervised spawn: the closure is `Fn` because a restart rebuilds
        // the backend from the same Send recipe — every incarnation is
        // config-identical to the first.
        let EngineHandle { cmd_tx, result_rx, join } = EngineHandle::spawn_supervised(
            move || Engine::new(recipe.build()?, ecfg.clone()),
            spec.restart_policy(),
            status.clone(),
            trace.clone(),
        );

        let results = Arc::new(ResultStore::default());
        let in_flight = Arc::new(AtomicU64::new(0));
        let swept = Arc::new(AtomicU64::new(0));
        let ttl_ms = Arc::new(AtomicU64::new(RESULT_TTL.as_millis() as u64));
        let kv_reserved = Arc::new(AtomicU64::new(0));
        let kv_reservations = Arc::new(Mutex::new(HashMap::new()));

        // Result pump: engine thread -> timestamped store. Releases the
        // request's worst-case KV page reservation, sweeps on every
        // delivery and on an idle tick, so orphans die even when traffic
        // stops. Exits when the engine thread drops its sender.
        let pump = {
            let results = results.clone();
            let in_flight = in_flight.clone();
            let swept = swept.clone();
            let ttl_ms = ttl_ms.clone();
            let kv_reserved = kv_reserved.clone();
            let kv_reservations: Arc<Mutex<HashMap<u64, u64>>> = kv_reservations.clone();
            std::thread::spawn(move || loop {
                let ttl = Duration::from_millis(ttl_ms.load(Ordering::Relaxed));
                match result_rx.recv_timeout(SWEEP_TICK) {
                    Ok(res) => {
                        if let Some(pages) = kv_reservations
                            .lock()
                            .unwrap_or_else(PoisonError::into_inner)
                            .remove(&res.id)
                        {
                            kv_reserved.fetch_sub(pages, Ordering::SeqCst);
                        }
                        in_flight.fetch_sub(1, Ordering::SeqCst);
                        results.insert(res);
                        swept.fetch_add(results.sweep(ttl) as u64, Ordering::Relaxed);
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        swept.fetch_add(results.sweep(ttl) as u64, Ordering::Relaxed);
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            })
        };

        Ok(Deployment {
            spec,
            backend_kind,
            max_seq,
            cmd_tx,
            status,
            trace,
            results,
            next_id: AtomicU64::new(1),
            in_flight,
            kv_pages_total,
            kv_layout,
            kv_reserved,
            kv_reservations,
            submitting: AtomicU64::new(0),
            submitted: AtomicU64::new(0),
            shed_capacity: AtomicU64::new(0),
            shed_memory: AtomicU64::new(0),
            shed_unhealthy: AtomicU64::new(0),
            swept,
            ttl_ms,
            draining: AtomicBool::new(false),
            engine_join: Mutex::new(Some(join)),
            pump_join: Mutex::new(Some(pump)),
        })
    }

    pub fn backend_kind(&self) -> &'static str {
        self.backend_kind
    }

    /// Longest prompt a request generating `gen_len` tokens can carry
    /// without being rejected at engine admission.
    pub fn max_prompt(&self, gen_len: usize) -> usize {
        self.max_seq.saturating_sub(gen_len).max(1)
    }

    /// Allocate a request id unique within this deployment.
    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::SeqCst)
    }

    /// Admission-controlled submit. `req.id` should come from
    /// [`Deployment::fresh_id`]. Returns `Shed` when `max_inflight`
    /// requests are already in flight; errors when the deployment is
    /// draining or its engine thread is gone.
    pub fn submit(&self, req: GenRequest) -> Result<Admission> {
        // Enter the submit window *before* the draining check: shutdown
        // sets `draining` and then waits for this gauge to drop to zero,
        // so a submit that saw draining=false completes its send before
        // the Shutdown command is enqueued.
        self.submitting.fetch_add(1, Ordering::SeqCst);
        let out = self.submit_gated(req);
        self.submitting.fetch_sub(1, Ordering::SeqCst);
        out
    }

    /// Worst-case KV pages this request can grow to — the shared
    /// `PoolLayout::worst_case_pages` formula `Engine::request_pages` also
    /// uses, so gate and engine cannot drift.
    fn worst_case_pages(&self, req: &GenRequest) -> u64 {
        let want = req.prompt.len() + req.max_new_tokens;
        self.kv_layout.worst_case_pages(want, self.max_seq) as u64
    }

    fn submit_gated(&self, mut req: GenRequest) -> Result<Admission> {
        if self.draining.load(Ordering::SeqCst) {
            bail!("model '{}' is draining", self.spec.name);
        }
        // Shed while the engine is down: during a restart window (or
        // after the restart budget is spent) new work gets an immediate
        // 503 instead of queueing into a dead incarnation. `Starting`
        // admits — the initial build is healthy-in-progress and the
        // commands queue in order.
        if matches!(self.status.health(), Health::Unhealthy | Health::Failed) {
            self.shed_unhealthy.fetch_add(1, Ordering::SeqCst);
            return Ok(Admission::Shed(ShedReason::Unhealthy));
        }
        // The spec's default deadline applies unless the request carries
        // its own.
        if req.deadline_ms == 0 {
            req.deadline_ms = self.spec.deadline_ms;
        }
        // Reserve an in-flight slot or shed: CAS loop so concurrent HTTP
        // workers cannot overshoot the bound.
        let limit = self.spec.max_inflight as u64;
        let mut cur = self.in_flight.load(Ordering::SeqCst);
        loop {
            if cur >= limit {
                self.shed_capacity.fetch_add(1, Ordering::SeqCst);
                return Ok(Admission::Shed(ShedReason::Capacity));
            }
            match self.in_flight.compare_exchange(
                cur,
                cur + 1,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
        // Reserve the worst-case page growth against the KV budget (same
        // CAS discipline); rolled back with the in-flight slot on failure.
        let need = self.worst_case_pages(&req);
        if let Some(total) = self.kv_pages_total {
            if need > total {
                // permanently over budget: no amount of retrying helps
                self.in_flight.fetch_sub(1, Ordering::SeqCst);
                self.shed_memory.fetch_add(1, Ordering::SeqCst);
                return Ok(Admission::Shed(ShedReason::OverBudget));
            }
            let mut cur = self.kv_reserved.load(Ordering::SeqCst);
            loop {
                if cur + need > total {
                    self.in_flight.fetch_sub(1, Ordering::SeqCst);
                    self.shed_memory.fetch_add(1, Ordering::SeqCst);
                    return Ok(Admission::Shed(ShedReason::KvMemory));
                }
                match self.kv_reserved.compare_exchange(
                    cur,
                    cur + need,
                    Ordering::SeqCst,
                    Ordering::SeqCst,
                ) {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
            self.kv_reservations
                .lock()
                .unwrap_or_else(PoisonError::into_inner)
                .insert(req.id, need);
        }
        let id = req.id;
        if self.cmd_tx.send(EngineCmd::Submit(req)).is_err() {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            if self.kv_pages_total.is_some() {
                if let Some(pages) = self
                    .kv_reservations
                    .lock()
                    .unwrap_or_else(PoisonError::into_inner)
                    .remove(&id)
                {
                    self.kv_reserved.fetch_sub(pages, Ordering::SeqCst);
                }
            }
            bail!("engine thread for model '{}' is gone", self.spec.name);
        }
        self.submitted.fetch_add(1, Ordering::SeqCst);
        Ok(Admission::Accepted)
    }

    /// Non-blocking result pickup.
    pub fn take_result(&self, id: u64) -> Option<GenResult> {
        self.results.take(id)
    }

    /// Cancel an in-flight request: the engine retires its lane and frees
    /// its KV pages immediately; the waiter receives a terminal
    /// `Cancelled` result (with partial tokens) through the normal pump.
    /// Unknown/finished ids are a no-op, so the HTTP worker can fire this
    /// on any disconnect without racing completion.
    pub fn cancel(&self, id: u64) {
        let _ = self.cmd_tx.send(EngineCmd::Cancel(id));
    }

    /// Live engine health (supervisor-published).
    pub fn health(&self) -> Health {
        self.status.health()
    }

    /// The deployment's flight recorder (shared across engine
    /// incarnations — `GET /trace` and `GET /trace/postmortem` read it).
    pub fn trace(&self) -> &Arc<TraceRecorder> {
        &self.trace
    }

    /// Blocking result pickup with a deadline (the HTTP worker path).
    pub fn wait_result(&self, id: u64, deadline: Duration) -> Option<GenResult> {
        let end = Instant::now() + deadline;
        loop {
            if let Some(r) = self.results.take(id) {
                return Some(r);
            }
            if Instant::now() >= end {
                return None;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Engine metrics snapshot (cross-thread round trip).
    pub fn stats(&self) -> Result<Snapshot> {
        let (tx, rx) = mpsc::channel();
        self.cmd_tx
            .send(EngineCmd::Stats(tx))
            .map_err(|_| anyhow::anyhow!("engine thread for model '{}' is gone", self.spec.name))?;
        rx.recv_timeout(Duration::from_secs(5))
            .with_context(|| format!("stats timeout for model '{}'", self.spec.name))
    }

    pub fn admission_stats(&self) -> AdmissionStats {
        let shed_capacity = self.shed_capacity.load(Ordering::SeqCst);
        let shed_memory = self.shed_memory.load(Ordering::SeqCst);
        let shed_unhealthy = self.shed_unhealthy.load(Ordering::SeqCst);
        AdmissionStats {
            queue_depth: self.in_flight.load(Ordering::SeqCst),
            submitted: self.submitted.load(Ordering::SeqCst),
            shed: shed_capacity + shed_memory + shed_unhealthy,
            shed_capacity,
            shed_memory,
            shed_unhealthy,
            engine_restarts: self.status.restarts(),
            kv_reserved_pages: self.kv_reserved.load(Ordering::SeqCst),
            kv_pages_total: self.kv_pages_total.unwrap_or(0),
            swept_results: self.swept.load(Ordering::Relaxed),
        }
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Override the orphan-result TTL (tests; ops tuning).
    pub fn set_result_ttl(&self, ttl: Duration) {
        self.ttl_ms.store(ttl.as_millis() as u64, Ordering::Relaxed);
    }

    /// Graceful shutdown: stop admitting, let the engine drain its
    /// in-flight lanes (`EngineCmd::Shutdown` finishes queued + active
    /// work and flushes every result before exiting), then join both
    /// threads. Idempotent; results stay in the store for late pickups.
    pub fn shutdown(&self) -> Result<()> {
        self.draining.store(true, Ordering::SeqCst);
        // Let in-progress submit calls finish their sends (see
        // `submitting`): the engine then sees every accepted Submit
        // before the Shutdown command and drains it.
        while self.submitting.load(Ordering::SeqCst) > 0 {
            std::thread::yield_now();
        }
        let _ = self.cmd_tx.send(EngineCmd::Shutdown);
        if let Some(j) = self.engine_join.lock().unwrap().take() {
            j.join().map_err(|_| anyhow::anyhow!("engine thread panicked"))?;
        }
        if let Some(j) = self.pump_join.lock().unwrap().take() {
            j.join().map_err(|_| anyhow::anyhow!("result pump panicked"))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    fn result(id: u64) -> GenResult {
        GenResult {
            id,
            tokens: vec![1, 2],
            prompt_logprobs: vec![],
            gen_logprobs: vec![],
            finish: FinishReason::Length,
            ttft_us: 0,
            total_us: 0,
            timings: crate::coordinator::request::ReqTimings::default(),
        }
    }

    #[test]
    fn store_take_removes_entry() {
        let s = ResultStore::default();
        s.insert(result(7));
        assert_eq!(s.len(), 1);
        assert!(s.take(7).is_some());
        assert!(s.take(7).is_none(), "delivered results must be evicted");
        assert!(s.is_empty());
    }

    #[test]
    fn store_sweep_evicts_only_expired() {
        let s = ResultStore::default();
        s.insert(result(1));
        std::thread::sleep(Duration::from_millis(20));
        s.insert(result(2));
        // entry 1 is ~20ms old, entry 2 fresh: a 10ms TTL drops only 1
        let dropped = s.sweep(Duration::from_millis(10));
        assert_eq!(dropped, 1);
        assert!(s.take(1).is_none());
        assert!(s.take(2).is_some());
        // a generous TTL drops nothing
        s.insert(result(3));
        assert_eq!(s.sweep(Duration::from_secs(60)), 0);
    }

    #[test]
    fn deployment_runs_and_drains() {
        let spec =
            DeploymentSpec::parse_kv("name=t,backend=native,seed=3,batch=2,queue=4").unwrap();
        let dep = Deployment::launch(spec, "no-such-dir").unwrap();
        assert_eq!(dep.backend_kind(), "native");
        assert!(dep.max_prompt(24) >= 1);

        let id = dep.fresh_id();
        let req = GenRequest::new(id, vec![104, 101, 108, 108, 111], 8);
        assert_eq!(dep.submit(req).unwrap(), Admission::Accepted);
        let res = dep.wait_result(id, Duration::from_secs(30)).expect("result");
        assert_eq!(res.id, id);
        assert_eq!(res.tokens.len(), 8);

        let adm = dep.admission_stats();
        assert_eq!(adm.submitted, 1);
        assert_eq!(adm.shed, 0);
        assert_eq!(adm.queue_depth, 0);

        dep.shutdown().unwrap();
        dep.shutdown().unwrap(); // idempotent
        assert!(dep.submit(GenRequest::new(99, vec![1], 1)).is_err(), "drained rejects submits");
    }

    #[test]
    fn memory_pressure_sheds_with_distinct_reasons() {
        // tiny model: page = 16 slots · 2 layers · 2 kv-heads · (8+8) dims
        // · 4 B = 4096 B; a 0.01 MiB budget buys exactly 2 pages
        let spec =
            DeploymentSpec::parse_kv("name=mem,backend=native,seed=1,batch=2,queue=8,kv_mb=0.01")
                .unwrap();
        let dep = Deployment::launch(spec, "no-such-dir").unwrap();
        assert_eq!(dep.admission_stats().kv_pages_total, 2);

        // worst case 64 slots = 4 pages > the entire 2-page budget →
        // permanent shed (no retry can succeed)
        let big = GenRequest::new(dep.fresh_id(), vec![65; 34], 30);
        assert_eq!(dep.submit(big).unwrap(), Admission::Shed(ShedReason::OverBudget));

        // a 2-page occupant exhausts the budget; a 1-page request then
        // sheds *transiently* while the occupant runs
        let id = dep.fresh_id();
        assert_eq!(
            dep.submit(GenRequest::new(id, vec![65; 10], 20)).unwrap(),
            Admission::Accepted
        );
        let second = GenRequest::new(dep.fresh_id(), vec![65; 5], 8);
        assert_eq!(dep.submit(second).unwrap(), Admission::Shed(ShedReason::KvMemory));
        let res = dep.wait_result(id, Duration::from_secs(30)).expect("result");
        assert_eq!(res.tokens.len(), 20);

        let adm = dep.admission_stats();
        assert_eq!(adm.shed_memory, 2, "both memory sheds count");
        assert_eq!(adm.shed_capacity, 0);
        assert_eq!(adm.shed, 2);
        assert_eq!(adm.kv_reserved_pages, 0, "completion released the reservation");

        // once the occupant finished, the transient condition cleared
        let id3 = dep.fresh_id();
        assert_eq!(
            dep.submit(GenRequest::new(id3, vec![65; 5], 8)).unwrap(),
            Admission::Accepted
        );
        assert!(dep.wait_result(id3, Duration::from_secs(30)).is_some());
        dep.shutdown().unwrap();
    }

    #[test]
    fn zero_page_budgets_are_rejected_at_launch() {
        // 0.001 MiB < one 4096 B page: would shed 100% of traffic while
        // /metrics looks identical to an unlimited deployment — launch
        // must refuse it
        let spec = DeploymentSpec::parse_kv("name=z,backend=native,kv_mb=0.001").unwrap();
        let err = Deployment::launch(spec, "no-such-dir");
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("zero"));
    }

    #[test]
    fn orphaned_results_are_ttl_swept() {
        let spec =
            DeploymentSpec::parse_kv("name=orphan,backend=native,seed=1,batch=1,queue=2").unwrap();
        let dep = Deployment::launch(spec, "no-such-dir").unwrap();
        dep.set_result_ttl(Duration::from_millis(1));
        let id = dep.fresh_id();
        dep.submit(GenRequest::new(id, vec![104, 105], 4)).unwrap();
        // never take the result: the pump's sweep must evict it
        let deadline = Instant::now() + Duration::from_secs(10);
        while dep.admission_stats().swept_results == 0 {
            assert!(Instant::now() < deadline, "orphan was never swept");
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(dep.results.is_empty());
        dep.shutdown().unwrap();
    }
}
