//! Multi-model registry: a fleet of named AQUA deployments behind one
//! server.
//!
//! AQUA's knob is only a *serving* lever if one process can host several
//! operating points at once — an exact `k=1.0` deployment next to an
//! aggressive `k=0.25` one, a sharded backend next to a single-threaded
//! native one — and route traffic between them. The registry owns N named
//! [`Deployment`]s (each an engine on its own thread, see
//! [`deployment`]), resolves request routing (`POST /generate` carries a
//! `"model"` field; the fleet default is used when omitted), and makes
//! the fleet mutable at runtime (`POST /models`, `DELETE /models/{name}`
//! — removal drains in-flight work before joining the engine thread).
//!
//! Fleet-config JSON (`aqua serve --fleet fleet.json`; the `models`
//! entries are [`DeploymentSpec::from_json`] documents):
//!
//! ```json
//! {
//!   "default": "exact",
//!   "models": [
//!     {"name": "exact",  "backend": "native", "k_ratio": 1.0},
//!     {"name": "pruned", "backend": "native", "k_ratio": 0.25,
//!      "batch": 8, "max_inflight": 16, "seed": 0}
//!   ]
//! }
//! ```

pub mod deployment;
pub mod spec;

pub use deployment::{Admission, AdmissionStats, Deployment, ResultStore, ShedReason, RESULT_TTL};
pub use spec::{DeploymentSpec, DEFAULT_MAX_INFLIGHT};

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Default)]
struct Inner {
    deployments: BTreeMap<String, Arc<Deployment>>,
    default_name: Option<String>,
}

/// The fleet: named deployments plus the default-model pointer.
pub struct ModelRegistry {
    arts_dir: String,
    inner: RwLock<Inner>,
}

impl ModelRegistry {
    pub fn new(arts_dir: &str) -> ModelRegistry {
        ModelRegistry { arts_dir: arts_dir.to_string(), inner: RwLock::new(Inner::default()) }
    }

    /// Launch and register a deployment. The first one becomes the fleet
    /// default. Errors (without disturbing the fleet) if the name is taken
    /// or the backend fails to resolve.
    pub fn deploy(&self, spec: DeploymentSpec) -> Result<()> {
        if self.inner.read().unwrap().deployments.contains_key(&spec.name) {
            bail!("model '{}' already exists", spec.name);
        }
        let name = spec.name.clone();
        let dep = Arc::new(Deployment::launch(spec, &self.arts_dir)?);
        let mut g = self.inner.write().unwrap();
        if g.deployments.contains_key(&name) {
            // lost a race with a concurrent deploy of the same name
            drop(g);
            let _ = dep.shutdown();
            bail!("model '{name}' already exists");
        }
        if g.default_name.is_none() {
            g.default_name = Some(name.clone());
        }
        g.deployments.insert(name, dep);
        Ok(())
    }

    /// Remove a deployment: unlist it first (new requests 404), then drain
    /// its in-flight work and join the engine thread. Clients already
    /// polling keep their handle on the deployment and still receive
    /// results. If it was the default, the first remaining model (by
    /// name) takes over.
    pub fn remove(&self, name: &str) -> Result<()> {
        let dep = {
            let mut g = self.inner.write().unwrap();
            let dep = g
                .deployments
                .remove(name)
                .with_context(|| format!("no model named '{name}'"))?;
            if g.default_name.as_deref() == Some(name) {
                g.default_name = g.deployments.keys().next().cloned();
            }
            dep
        };
        dep.shutdown()
    }

    /// Resolve a request's deployment: by name, or the fleet default when
    /// `None`.
    pub fn get(&self, name: Option<&str>) -> Option<Arc<Deployment>> {
        let g = self.inner.read().unwrap();
        let key = match name {
            Some(n) => n,
            None => g.default_name.as_deref()?,
        };
        g.deployments.get(key).cloned()
    }

    pub fn default_name(&self) -> Option<String> {
        self.inner.read().unwrap().default_name.clone()
    }

    /// Point the fleet default at an existing deployment.
    pub fn set_default(&self, name: &str) -> Result<()> {
        let mut g = self.inner.write().unwrap();
        if !g.deployments.contains_key(name) {
            bail!("no model named '{name}'");
        }
        g.default_name = Some(name.to_string());
        Ok(())
    }

    /// Deployment names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.inner.read().unwrap().deployments.keys().cloned().collect()
    }

    /// A point-in-time snapshot of every deployment (sorted by name).
    pub fn deployments(&self) -> Vec<Arc<Deployment>> {
        self.inner.read().unwrap().deployments.values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.read().unwrap().deployments.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain and join every deployment (process shutdown).
    pub fn shutdown_all(&self) -> Result<()> {
        let deps: Vec<Arc<Deployment>> = {
            let mut g = self.inner.write().unwrap();
            g.default_name = None;
            std::mem::take(&mut g.deployments).into_values().collect()
        };
        for d in deps {
            d.shutdown()?;
        }
        Ok(())
    }

    /// Build a fleet from a fleet-config JSON document (format in the
    /// module docs).
    pub fn from_fleet_json(doc: &Json, arts_dir: &str) -> Result<ModelRegistry> {
        let reg = ModelRegistry::new(arts_dir);
        let models = doc.get("models").as_arr().context("fleet config needs a 'models' array")?;
        if models.is_empty() {
            bail!("fleet config: 'models' is empty");
        }
        for m in models {
            reg.deploy(DeploymentSpec::from_json(m)?)?;
        }
        if let Some(d) = doc.get("default").as_str() {
            reg.set_default(d).context("fleet config 'default'")?;
        }
        Ok(reg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native(name: &str, k: f64) -> DeploymentSpec {
        DeploymentSpec::parse_kv(&format!("name={name},backend=native,k={k},batch=2,queue=4"))
            .unwrap()
    }

    #[test]
    fn deploy_get_remove_default_fallback() {
        let reg = ModelRegistry::new("no-such-dir");
        assert!(reg.is_empty());
        assert!(reg.get(None).is_none());
        reg.deploy(native("a", 1.0)).unwrap();
        reg.deploy(native("b", 0.5)).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.default_name().as_deref(), Some("a"), "first deploy is default");
        assert_eq!(reg.get(None).unwrap().spec.name, "a");
        assert_eq!(reg.get(Some("b")).unwrap().spec.name, "b");
        assert!(reg.get(Some("zzz")).is_none());

        assert!(reg.deploy(native("a", 0.25)).is_err(), "duplicate name rejected");

        reg.remove("a").unwrap();
        assert_eq!(reg.default_name().as_deref(), Some("b"), "default falls to survivor");
        assert!(reg.remove("a").is_err(), "double remove errors");
        reg.shutdown_all().unwrap();
        assert!(reg.is_empty());
    }

    #[test]
    fn fleet_json_builds_and_sets_default() {
        let doc = Json::parse(
            r#"{"default": "b",
                "models": [{"name": "a", "backend": "native", "k_ratio": 1.0, "batch": 2},
                           {"name": "b", "backend": "native", "k_ratio": 0.5, "batch": 2}]}"#,
        )
        .unwrap();
        let reg = ModelRegistry::from_fleet_json(&doc, "no-such-dir").unwrap();
        assert_eq!(reg.names(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(reg.default_name().as_deref(), Some("b"));
        reg.shutdown_all().unwrap();

        // bad configs
        assert!(ModelRegistry::from_fleet_json(&Json::parse("{}").unwrap(), "x").is_err());
        let empty = Json::parse(r#"{"models": []}"#).unwrap();
        assert!(ModelRegistry::from_fleet_json(&empty, "x").is_err());
        let bad_default =
            Json::parse(r#"{"default": "z", "models": [{"name": "a", "backend": "native"}]}"#)
                .unwrap();
        assert!(ModelRegistry::from_fleet_json(&bad_default, "x").is_err());
    }
}
