//! Model configuration, mirrored 1:1 from `python/compile/config.py`
//! through the manifest.

use anyhow::{bail, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub rope_theta: f64,
    pub norm_eps: f64,
    /// KV-cache capacity S (slots per lane).
    pub max_seq: usize,
    pub train_seq: usize,
}

impl ModelConfig {
    pub fn from_json(name: &str, j: &Json) -> Result<ModelConfig> {
        let num = |k: &str| -> Result<usize> { Ok(j.req_i64(k)? as usize) };
        let cfg = ModelConfig {
            name: name.to_string(),
            vocab: num("vocab")?,
            d_model: num("d_model")?,
            n_layers: num("n_layers")?,
            n_q_heads: num("n_q_heads")?,
            n_kv_heads: num("n_kv_heads")?,
            d_head: num("d_head")?,
            d_ff: num("d_ff")?,
            rope_theta: j.get("rope_theta").as_f64().unwrap_or(10_000.0),
            norm_eps: j.get("norm_eps").as_f64().unwrap_or(1e-5),
            max_seq: num("max_seq")?,
            train_seq: num("train_seq")?,
        };
        if cfg.n_q_heads % cfg.n_kv_heads != 0 {
            bail!("n_q_heads must be a multiple of n_kv_heads");
        }
        Ok(cfg)
    }

    /// Shape for the hermetic native backend: small enough that engine
    /// integration tests run in milliseconds, structured enough (GQA,
    /// multiple layers, byte vocab) to exercise every serving-path branch.
    pub fn tiny(name: &str) -> ModelConfig {
        ModelConfig {
            name: name.to_string(),
            vocab: crate::tokenizer::VOCAB,
            d_model: 32,
            n_layers: 2,
            n_q_heads: 4,
            n_kv_heads: 2,
            d_head: 8,
            d_ff: 64,
            rope_theta: 10_000.0,
            norm_eps: 1e-5,
            max_seq: 160,
            train_seq: 64,
        }
    }

    /// GQA group size N_Q (paper §6.3).
    pub fn group_size(&self) -> usize {
        self.n_q_heads / self.n_kv_heads
    }

    pub fn is_mha(&self) -> bool {
        self.n_kv_heads == self.n_q_heads
    }

    /// Elements in one lane's K or V cache row: S × n_kv × d.
    pub fn cache_row_elems(&self) -> usize {
        self.max_seq * self.n_kv_heads * self.d_head
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{"vocab":256,"d_model":128,"n_layers":4,"n_q_heads":4,
                "n_kv_heads":1,"d_head":32,"d_ff":512,"rope_theta":10000.0,
                "norm_eps":1e-5,"max_seq":512,"train_seq":192}"#,
        )
        .unwrap()
    }

    #[test]
    fn parse_and_derived() {
        let c = ModelConfig::from_json("llama-analog", &sample()).unwrap();
        assert_eq!(c.group_size(), 4);
        assert!(!c.is_mha());
        assert_eq!(c.cache_row_elems(), 512 * 32);
    }

    #[test]
    fn tiny_is_well_formed() {
        let c = ModelConfig::tiny("native-test");
        assert_eq!(c.vocab, 256);
        assert_eq!(c.n_q_heads % c.n_kv_heads, 0);
        assert!(c.d_head >= 4 && c.max_seq >= 2 * c.train_seq);
        assert_eq!(c.group_size(), 2);
    }

    #[test]
    fn rejects_bad_heads() {
        let mut j = sample();
        if let Json::Obj(o) = &mut j {
            o.insert("n_kv_heads".into(), Json::Num(3.0));
        }
        assert!(ModelConfig::from_json("x", &j).is_err());
    }
}
