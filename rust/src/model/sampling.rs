//! Sampling strategies over the decode logits.

use crate::tensor::softmax::{argmax, softmax_inplace};
use crate::util::prng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampler {
    /// Deterministic argmax (the paper's Table-7 qualitative setting,
    /// `do_sample=False`).
    Greedy,
    /// Temperature sampling (τ > 0).
    Temperature(f32),
    /// Top-k then temperature.
    TopK(usize, f32),
}

impl Sampler {
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> i32 {
        match *self {
            Sampler::Greedy => argmax(logits) as i32,
            Sampler::Temperature(t) => {
                let mut p: Vec<f32> = logits.iter().map(|&x| x / t.max(1e-6)).collect();
                softmax_inplace(&mut p);
                weighted_pick(&p, rng)
            }
            Sampler::TopK(k, t) => {
                let mut idx: Vec<usize> = (0..logits.len()).collect();
                idx.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
                idx.truncate(k.max(1));
                let mut p: Vec<f32> = idx.iter().map(|&i| logits[i] / t.max(1e-6)).collect();
                softmax_inplace(&mut p);
                let j = weighted_pick(&p, rng) as usize;
                idx[j] as i32
            }
        }
    }
}

fn weighted_pick(probs: &[f32], rng: &mut Rng) -> i32 {
    let mut r = rng.f32();
    for (i, &p) in probs.iter().enumerate() {
        r -= p;
        if r <= 0.0 {
            return i as i32;
        }
    }
    (probs.len() - 1) as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_is_argmax() {
        let mut rng = Rng::new(0);
        let logits = vec![0.1, 5.0, -1.0];
        assert_eq!(Sampler::Greedy.sample(&logits, &mut rng), 1);
    }

    #[test]
    fn low_temperature_concentrates() {
        let mut rng = Rng::new(1);
        let logits = vec![0.0, 10.0, 0.0];
        for _ in 0..50 {
            assert_eq!(Sampler::Temperature(0.1).sample(&logits, &mut rng), 1);
        }
    }

    #[test]
    fn topk_restricts_support() {
        let mut rng = Rng::new(2);
        let logits = vec![1.0, 2.0, 3.0, -5.0];
        for _ in 0..100 {
            let s = Sampler::TopK(2, 1.0).sample(&logits, &mut rng);
            assert!(s == 2 || s == 1, "sampled outside top-2: {s}");
        }
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let mut rng = Rng::new(3);
        let logits = vec![1.0f32, 1.0, 1.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[Sampler::Temperature(1.0).sample(&logits, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
