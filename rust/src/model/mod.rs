//! Model-side types: configuration (mirrored from the manifest), parameter
//! loading, and sampling.

pub mod config;
pub mod sampling;

pub use config::ModelConfig;
