//! Deterministic PRNG (the `rand` facade is unavailable offline).
//!
//! SplitMix64 for seeding + xoshiro256** for the stream — the standard
//! pairing, adequate for sampling, workload generation, and property tests.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Fill a vec with N(0, std) f32 samples.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, w: &[f64]) -> usize {
        let total: f64 = w.iter().sum();
        let mut r = self.f64() * total;
        for (i, &x) in w.iter().enumerate() {
            r -= x;
            if r <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            let n = r.range(5, 10);
            assert!((5..10).contains(&n));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.05, "mean {m}");
        assert!((v - 1.0).abs() < 0.1, "var {v}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(9);
        let mut hits = [0usize; 3];
        for _ in 0..3000 {
            hits[r.weighted(&[0.1, 0.1, 0.8])] += 1;
        }
        assert!(hits[2] > hits[0] + hits[1]);
    }
}
