//! Small self-contained substrates.
//!
//! The offline crate registry in this image only carries the `xla`
//! dependency tree, so the usual suspects (serde, rand, tracing) are
//! unavailable — these modules stand in for them and are tested like any
//! other library code (see DESIGN.md "Substitutions").

pub mod json;
pub mod logging;
pub mod prng;
pub mod testkit;

/// Format a `std::time::Duration` compactly (`1.23ms`, `45.6µs`, `2.1s`).
pub fn fmt_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len().max(1) as f64).sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_formatting() {
        use std::time::Duration;
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12ns");
        assert!(fmt_duration(Duration::from_micros(42)).ends_with("µs"));
        assert!(fmt_duration(Duration::from_millis(7)).ends_with("ms"));
        assert!(fmt_duration(Duration::from_secs(3)).ends_with('s'));
    }

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!(stddev(&xs) > 1.0 && stddev(&xs) < 1.2);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
