//! Minimal JSON parser/printer (serde is unavailable offline).
//!
//! Supports the full JSON grammar the build path emits: objects, arrays,
//! strings (with \uXXXX escapes incl. surrogate pairs), numbers, bools,
//! null. Property-tested round-trip in `tests/proptest_json.rs`.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON document. Object keys are kept sorted (BTreeMap) so printing is
/// deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `doc.get("a").get("b")` style traversal; missing keys yield Null.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Required-field helpers with decent error messages.
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.get(key).as_str().ok_or_else(|| anyhow!("missing string field '{key}'"))
    }

    pub fn req_i64(&self, key: &str) -> Result<i64> {
        self.get(key).as_i64().ok_or_else(|| anyhow!("missing number field '{key}'"))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                0x10000 + (((hi - 0xD800) as u32) << 10) + (lo - 0xDC00) as u32
                            } else {
                                hi as u32
                            };
                            s.push(char::from_u32(cp).ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let len = utf8_len(c)?;
                    let start = self.i - 1;
                    self.i += len - 1;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| anyhow!("invalid UTF-8 in string"))?,
                    );
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16> {
        if self.i + 4 > self.b.len() {
            bail!("truncated \\u escape");
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
        self.i += 4;
        Ok(u16::from_str_radix(s, 16)?)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| anyhow!("bad number '{s}'"))?))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0x00..=0x7F => Ok(1),
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte {first:#x}"),
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "s": "x\ny"}"#)
            .unwrap();
        assert_eq!(j.get("a").idx(1).as_f64(), Some(2.5));
        assert_eq!(j.get("a").idx(2).as_f64(), Some(-300.0));
        assert_eq!(j.get("b").get("c").as_bool(), Some(true));
        assert_eq!(j.get("b").get("d"), &Json::Null);
        assert_eq!(j.get("s").as_str(), Some("x\ny"));
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escapes() {
        let j = Json::parse(r#""café 😀""#).unwrap();
        assert_eq!(j.as_str(), Some("café 😀"));
        // raw multi-byte UTF-8 passes through
        let j = Json::parse("\"héllo ÿ\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo ÿ"));
    }

    #[test]
    fn roundtrip_display() {
        let src = r#"{"arr":[1,2,{"k":"v"}],"n":-1.5,"t":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }
}
