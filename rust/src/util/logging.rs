//! Tiny leveled logger (tracing/env_logger unavailable offline).
//!
//! Level picked from `AQUA_LOG` (`error|warn|info|debug|trace`), default
//! `info`. Thread-safe via a global atomic; output goes to stderr so stdout
//! stays clean for table/figure data.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("AQUA_LOG").unwrap_or_default().to_lowercase().as_str() {
        "error" => Level::Error,
        "warn" => Level::Warn,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == u8::MAX {
        init_from_env()
    } else {
        l
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
