//! Tiny leveled logger (tracing/env_logger unavailable offline).
//!
//! Spec picked from `AQUA_LOG`, default `info`. The spec is a comma list:
//! a bare level sets the default, `module=level` segments override by
//! module-path substring (longest match wins) — e.g.
//! `AQUA_LOG=info,engine=trace,server=warn` floods nothing but the engine.
//! Output goes to stderr (stdout stays clean for table/figure data), each
//! line stamped with monotonic seconds since the first log call so trace
//! timelines and stderr interleave on one clock.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn parse(s: &str) -> Option<Level> {
        match s {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static OVERRIDES: OnceLock<Vec<(String, Level)>> = OnceLock::new();
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Parse an `AQUA_LOG` spec into (default level, module overrides). Pure —
/// unit-testable without touching the process environment. Bare segments
/// set the default (unknown names fall back to `info`); `module=level`
/// segments become overrides (unknown levels skipped).
pub fn parse_spec(spec: &str) -> (Level, Vec<(String, Level)>) {
    let mut default = Level::Info;
    let mut overrides: Vec<(String, Level)> = vec![];
    for seg in spec.split(',') {
        let seg = seg.trim();
        if seg.is_empty() {
            continue;
        }
        match seg.split_once('=') {
            Some((module, lvl)) => {
                if let Some(l) = Level::parse(lvl.trim().to_lowercase().as_str()) {
                    overrides.push((module.trim().to_string(), l));
                }
            }
            None => {
                if let Some(l) = Level::parse(seg.to_lowercase().as_str()) {
                    default = l;
                }
            }
        }
    }
    (default, overrides)
}

fn init_from_env() -> u8 {
    let (default, overrides) = parse_spec(&std::env::var("AQUA_LOG").unwrap_or_default());
    let _ = OVERRIDES.set(overrides);
    LEVEL.store(default as u8, Ordering::Relaxed);
    default as u8
}

pub fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l == u8::MAX {
        init_from_env()
    } else {
        l
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Effective threshold for a module path: the longest matching
/// `module=level` override (substring match on `module_path!`), else the
/// default level.
fn threshold_for(module: &str) -> u8 {
    let default = level(); // also forces override init from env
    let mut best: Option<(usize, Level)> = None;
    for (pat, lvl) in OVERRIDES.get().map(|v| v.as_slice()).unwrap_or(&[]) {
        if module.contains(pat.as_str()) && best.map(|(len, _)| pat.len() > len).unwrap_or(true) {
            best = Some((pat.len(), *lvl));
        }
    }
    best.map(|(_, l)| l as u8).unwrap_or(default)
}

/// Whether `l` passes the *default* level (module overrides not applied —
/// use the macros for module-aware filtering).
pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if (l as u8) <= threshold_for(module) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let elapsed = EPOCH.get_or_init(Instant::now).elapsed();
        eprintln!("[{:10.3}s {tag}] {module}: {msg}", elapsed.as_secs_f64());
    }
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace,
                                   module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn spec_parsing() {
        let (d, o) = parse_spec("info,engine=trace,server=warn");
        assert_eq!(d, Level::Info);
        assert_eq!(o, vec![("engine".to_string(), Level::Trace), ("server".to_string(), Level::Warn)]);

        let (d, o) = parse_spec("");
        assert_eq!(d, Level::Info);
        assert!(o.is_empty());

        // unknown default name → info; unknown override level → skipped
        let (d, o) = parse_spec("loud,engine=shouty,kvpool=debug");
        assert_eq!(d, Level::Info);
        assert_eq!(o, vec![("kvpool".to_string(), Level::Debug)]);

        // bare level anywhere in the list still sets the default
        let (d, _) = parse_spec("engine=trace,error");
        assert_eq!(d, Level::Error);
    }

    #[test]
    fn longest_override_wins() {
        // exercised through parse_spec's output shape: the matching logic
        // prefers the longest pattern, here checked directly.
        let overrides =
            vec![("coordinator".to_string(), Level::Warn), ("coordinator::engine".to_string(), Level::Trace)];
        let module = "aqua_serve::coordinator::engine";
        let mut best: Option<(usize, Level)> = None;
        for (pat, lvl) in &overrides {
            if module.contains(pat.as_str()) && best.map(|(len, _)| pat.len() > len).unwrap_or(true) {
                best = Some((pat.len(), *lvl));
            }
        }
        assert_eq!(best.map(|(_, l)| l), Some(Level::Trace));
    }
}
