//! Property-test mini-framework (proptest is unavailable offline; see
//! DESIGN.md "Substitutions").
//!
//! `check(cases, gen, prop)` runs `prop` on `cases` generated inputs from a
//! seeded PRNG; on failure it performs a bounded greedy shrink by re-running
//! the generator with smaller "size" hints, and reports the seed so failures
//! reproduce exactly.

use super::prng::Rng;

/// Context handed to generators: a PRNG plus a size hint that shrinks on
/// failure.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn vec_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(n, std)
    }

    /// A "dimension-like" value in [1, size].
    pub fn dim(&mut self) -> usize {
        1 + self.rng.below(self.size.max(1))
    }
}

/// Run a property over `cases` random inputs. Panics with the failing seed
/// and (shrunken) case number on violation.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Gen) -> T,
    P: FnMut(&T) -> std::result::Result<(), String>,
    T: std::fmt::Debug,
{
    let base_seed = match std::env::var("AQUA_PROP_SEED") {
        Ok(s) => s.parse().unwrap_or(0xA17A),
        Err(_) => 0xA17A,
    };
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen { rng: Rng::new(seed), size: 8 + case % 64 };
        let input = gen(&mut g);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: retry with progressively smaller size hints on
            // the same seed, keep the smallest failing reproduction.
            let mut smallest: Option<(usize, T, String)> = None;
            for size in (1..g.size).rev() {
                let mut g2 = Gen { rng: Rng::new(seed), size };
                let cand = gen(&mut g2);
                if let Err(m) = prop(&cand) {
                    smallest = Some((size, cand, m));
                }
            }
            match smallest {
                Some((size, cand, m)) => panic!(
                    "property '{name}' failed (case {case}, seed {seed:#x}, shrunk size {size}): {m}\ninput: {cand:?}"
                ),
                None => panic!(
                    "property '{name}' failed (case {case}, seed {seed:#x}): {msg}\ninput: {input:?}"
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs-nonneg", 50, |g| {
            let d = g.dim();
            g.vec_f32(d, 1.0)
        }, |v| {
            if v.iter().all(|x| x.abs() >= 0.0) {
                Ok(())
            } else {
                Err("negative abs".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |g| g.dim(), |_| Err("nope".into()));
    }
}
