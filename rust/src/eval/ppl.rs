//! WikiText-analog perplexity: teacher-forced NLL over held-out corpus
//! windows, exp(mean NLL) — the paper's `WikiText (ppl ↓)` column.

use anyhow::{Context, Result};

use crate::coordinator::{Engine, GenRequest};

/// Configuration for a perplexity run.
#[derive(Debug, Clone, Copy)]
pub struct PplConfig {
    /// Window length in bytes (tokens).
    pub window: usize,
    /// Number of windows (evenly strided over the corpus).
    pub windows: usize,
}

impl Default for PplConfig {
    fn default() -> Self {
        PplConfig { window: 256, windows: 16 }
    }
}

impl PplConfig {
    /// The standard 256-byte scoring window, clamped to a backend's KV
    /// capacity (the native backend's tiny preset is smaller than the
    /// PJRT models; the margin leaves room for the final target byte).
    pub fn for_capacity(max_seq: usize, windows: usize) -> PplConfig {
        PplConfig { window: 256.min(max_seq.saturating_sub(8)), windows }
    }
}

/// Compute perplexity of the engine's model over `corpus` bytes.
pub fn perplexity(engine: &mut Engine, corpus: &[u8], cfg: PplConfig) -> Result<f64> {
    anyhow::ensure!(corpus.len() > cfg.window + 1, "corpus smaller than one window");
    let stride = ((corpus.len() - cfg.window - 1) / cfg.windows.max(1)).max(1);
    let mut reqs = vec![];
    for w in 0..cfg.windows {
        let start = (w * stride).min(corpus.len() - cfg.window - 1);
        let ids: Vec<i32> = corpus[start..start + cfg.window].iter().map(|&b| b as i32).collect();
        let mut r = GenRequest::new(w as u64 + 1, ids, 0);
        r.score_only = true;
        reqs.push(r);
    }
    let results = engine.run_batch(reqs).context("perplexity scoring")?;
    let mut nll = 0.0f64;
    let mut n = 0usize;
    for res in &results {
        for &lp in &res.prompt_logprobs {
            nll -= lp as f64;
            n += 1;
        }
    }
    anyhow::ensure!(n > 0, "no scored tokens");
    Ok((nll / n as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults() {
        let c = PplConfig::default();
        assert!(c.window > 0 && c.windows > 0);
    }

    #[test]
    fn window_clamps_to_capacity() {
        assert_eq!(PplConfig::for_capacity(512, 4).window, 256);
        assert_eq!(PplConfig::for_capacity(160, 4).window, 152);
        assert_eq!(PplConfig::for_capacity(4, 4).window, 0);
    }
}
