//! Regenerators for every table and figure in the paper's evaluation
//! (DESIGN.md "Experiment index"). Each function prints the same rows /
//! series the paper reports and returns the data for tests/benches.

use anyhow::{anyhow, Result};

use crate::aqua::policy::{AquaConfig, CostModel};
use crate::bench::Bencher;
use crate::coordinator::{Engine, EngineConfig};
use crate::eval::ppl::{perplexity, PplConfig};
use crate::eval::tasks::{run_task, EvalSummary, TaskSet};
use crate::runtime::{Artifacts, BackendSpec};

#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;

#[cfg(feature = "pjrt")]
use anyhow::Context;
#[cfg(feature = "pjrt")]
use xla::{FromRawBytes, Literal};

#[cfg(feature = "pjrt")]
use crate::aqua::info_loss::{loss_series, online_projection, Selection};
#[cfg(feature = "pjrt")]
use crate::aqua::overlap::overlap_stats;
#[cfg(feature = "pjrt")]
use crate::tensor::Tensor;

pub const TASK_ORDER: [&str; 6] = [
    "knowledge", "arithmetic", "completion", "coreference", "negation", "hard_completion",
];

// ---------------------------------------------------------------------------
// npz → Tensor helpers (calibration dumps only exist on the PJRT path)
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub fn load_dump(path: &std::path::Path) -> Result<BTreeMap<String, Tensor>> {
    let entries = Literal::read_npz(path, &()).map_err(|e| anyhow!("reading {path:?}: {e:?}"))?;
    let mut out = BTreeMap::new();
    for (name, lit) in entries {
        let shape = lit.array_shape().map_err(|e| anyhow!("{e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let lit32 = match shape.ty() {
            xla::ElementType::F32 => lit,
            _ => lit.convert(xla::ElementType::F32.primitive_type()).map_err(|e| anyhow!("{e:?}"))?,
        };
        let data = lit32.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        out.insert(name, Tensor::new(&dims, data)?);
    }
    Ok(out)
}

#[cfg(feature = "pjrt")]
fn stack_rows(parts: &[&Tensor]) -> Result<Tensor> {
    let cols = parts[0].cols();
    let mut data = vec![];
    for p in parts {
        anyhow::ensure!(p.cols() == cols, "column mismatch");
        data.extend_from_slice(p.data());
    }
    let rows = data.len() / cols;
    Tensor::new(&[rows, cols], data)
}

// ---------------------------------------------------------------------------
// Figure 2 — online-vs-offline projection × slice-vs-magnitude
// ---------------------------------------------------------------------------

pub struct Fig2Row {
    pub condition: String,
    pub series: Vec<(f64, f32)>,
}

#[cfg(feature = "pjrt")]
pub fn fig2(arts: &Artifacts, model: &str) -> Result<Vec<Fig2Row>> {
    let m = arts.model(model)?;
    let dump = load_dump(&m.calib_dump_npz)?;
    let gsz = m.config.group_size();
    // Pool the GQA group's matrices (Q0..Qn + K) from the *held-out eval*
    // split — the paper's Layer 0, Head 0 analysis.
    let mut parts: Vec<&Tensor> = vec![];
    for j in 0..gsz {
        parts.push(dump.get(&format!("eval_l0_q{j}")).context("dump missing eval q")?);
    }
    parts.push(dump.get("eval_l0_k").context("dump missing eval k")?);
    let data = stack_rows(&parts)?;
    let p_offline = dump.get("proj_l0_g0").context("dump missing proj")?;
    let p_online = online_projection(&data)?;

    let ratios = [0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0];
    let mut rows = vec![];
    for (pname, p) in [("Same Matrix (online SVD)", &p_online), ("Different Dataset (offline P)", p_offline)] {
        for (sname, sel) in [("Top-K by Dimension", Selection::ByDimension),
                             ("Top-K by Magnitude", Selection::ByMagnitude)] {
            rows.push(Fig2Row {
                condition: format!("{pname} / {sname}"),
                series: loss_series(&data, p, &ratios, sel)?,
            });
        }
    }
    Ok(rows)
}

pub fn print_fig2(rows: &[Fig2Row]) {
    println!("# Figure 2 — mean information-retention loss (L0, group 0)");
    print!("{:<48}", "condition \\ k/d");
    for (r, _) in &rows[0].series {
        print!(" {r:>7.3}");
    }
    println!();
    for row in rows {
        print!("{:<48}", row.condition);
        for (_, l) in &row.series {
            print!(" {l:>7.4}");
        }
        println!();
    }
}

// ---------------------------------------------------------------------------
// Figure 3/4 — cross-lingual transfer of the offline projection
// ---------------------------------------------------------------------------

pub struct Fig3Row {
    pub matrix: String,
    pub language: String,
    pub series: Vec<(f64, f32)>,
}

#[cfg(feature = "pjrt")]
pub fn fig3(arts: &Artifacts, model: &str) -> Result<Vec<Fig3Row>> {
    let m = arts.model(model)?;
    let dump = load_dump(&m.calib_dump_npz)?;
    let p = dump.get("proj_l0_g0").context("missing proj")?;
    let gsz = m.config.group_size();
    let ratios = [0.125, 0.25, 0.5, 0.75, 1.0];
    let mut rows = vec![];
    let mut matrices: Vec<(String, String)> = vec![("K".into(), "k".into())];
    for j in 0..gsz {
        matrices.push((format!("Q{j}"), format!("q{j}")));
    }
    for (label, key) in &matrices {
        for (lang, tag) in [("anglish (calibration lang)", "eval"), ("devan (cross-lingual)", "devan")] {
            let data = dump
                .get(&format!("{tag}_l0_{key}"))
                .with_context(|| format!("missing {tag}_l0_{key}"))?;
            rows.push(Fig3Row {
                matrix: label.clone(),
                language: lang.to_string(),
                series: loss_series(data, p, &ratios, Selection::ByMagnitude)?,
            });
        }
    }
    Ok(rows)
}

pub fn print_fig3(rows: &[Fig3Row]) {
    println!("# Figure 3/4 — cross-lingual info-retention loss (offline P, magnitude top-k)");
    print!("{:<10}{:<28}", "matrix", "language");
    for (r, _) in &rows[0].series {
        print!(" {r:>7.3}");
    }
    println!();
    for row in rows {
        print!("{:<10}{:<28}", row.matrix, row.language);
        for (_, l) in &row.series {
            print!(" {l:>7.4}");
        }
        println!();
    }
}

// ---------------------------------------------------------------------------
// Figure 5 — magnitude-vs-PCA overlap
// ---------------------------------------------------------------------------

#[cfg(feature = "pjrt")]
pub fn fig5(arts: &Artifacts, model: &str) -> Result<Vec<(String, Vec<crate::aqua::overlap::OverlapStats>)>> {
    let m = arts.model(model)?;
    let dump = load_dump(&m.calib_dump_npz)?;
    let p = dump.get("proj_last_g0").context("missing last-layer proj")?;
    let fracs = [0.125, 0.25, 0.5, 0.75];
    let mut out = vec![];
    for (label, key) in [("Query (Q0, last layer)", "eval_last_q0"), ("Key (last layer)", "eval_last_k")] {
        let data = dump.get(key).with_context(|| format!("missing {key}"))?;
        let mut stats = vec![];
        for &kf in &fracs {
            for &kp in &fracs {
                stats.push(overlap_stats(data, p, kf, kp));
            }
        }
        out.push((label.to_string(), stats));
    }
    Ok(out)
}

pub fn print_fig5(rows: &[(String, Vec<crate::aqua::overlap::OverlapStats>)]) {
    println!("# Figure 5 — overlap ρ between top-K magnitude dims and top-K' PCA dims (L{{last}})");
    for (label, stats) in rows {
        println!("\n{label}:");
        println!("{:>8} {:>8} {:>8} {:>8} {:>8} {:>8}", "K/d", "K'/d", "mean", "p10", "p50", "p90");
        for s in stats {
            println!(
                "{:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3}",
                s.k_frac, s.kp_frac, s.mean, s.p10, s.p50, s.p90
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Tables 1/2/3 — benchmark sweeps through the engine
// ---------------------------------------------------------------------------

/// One table row: the 6 task accuracies + perplexity for a knob setting.
pub struct TableRow {
    pub label: String,
    pub summaries: Vec<EvalSummary>,
    pub ppl: f64,
}

pub struct SweepOptions {
    pub batch: usize,
    pub items_per_task: usize,
    pub ppl_windows: usize,
    pub tasks: Vec<String>,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            batch: 4,
            items_per_task: 60,
            ppl_windows: 8,
            tasks: TASK_ORDER.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// Task sets + corpus loaded once per sweep — every table row reuses them
/// instead of re-reading the files per engine.
pub struct SweepData {
    pub sets: Vec<TaskSet>,
    pub corpus: Vec<u8>,
}

pub fn load_sweep_data(arts: &Artifacts, opt: &SweepOptions) -> Result<SweepData> {
    let mut sets = vec![];
    for tname in &opt.tasks {
        let (path, analog) = arts
            .tasks
            .get(tname)
            .ok_or_else(|| anyhow!("task '{tname}' missing from manifest"))?;
        sets.push(TaskSet::load(tname, analog, path)?.truncated(opt.items_per_task));
    }
    let corpus = std::fs::read(arts.corpus_path("valid")?)?;
    Ok(SweepData { sets, corpus })
}

pub fn eval_config(
    data: &SweepData,
    spec: &BackendSpec,
    aqua: AquaConfig,
    label: &str,
    opt: &SweepOptions,
) -> Result<TableRow> {
    let mut engine = Engine::with_spec(
        spec,
        EngineConfig { batch: opt.batch, aqua, ..Default::default() },
    )?;
    let mut summaries = vec![];
    for set in &data.sets {
        summaries.push(run_task(&mut engine, set)?);
    }
    let ppl = perplexity(
        &mut engine,
        &data.corpus,
        PplConfig::for_capacity(engine.model_config().max_seq, opt.ppl_windows),
    )?;
    crate::log_info!("config '{label}': {}", engine.metrics.snapshot().report());
    Ok(TableRow { label: label.to_string(), summaries, ppl })
}

pub fn print_table(title: &str, rows: &[TableRow]) {
    println!("# {title}");
    print!("{:<26}", "config");
    for t in &rows[0].summaries {
        print!(" {:>16}", format!("{}({})", t.task, t.analog_of));
    }
    println!(" {:>9}", "ppl");
    for r in rows {
        print!("{:<26}", r.label);
        for s in &r.summaries {
            print!(" {:>16}", format!("{:.3}±{:.3}", s.acc, s.stderr));
        }
        println!(" {:>9.3}", r.ppl);
    }
}

/// Table 1 / 4 — standalone AQUA sweep.
pub fn table1(
    arts: &Artifacts,
    spec: &BackendSpec,
    ratios: &[f64],
    opt: &SweepOptions,
) -> Result<Vec<TableRow>> {
    let data = load_sweep_data(arts, opt)?;
    let mut rows =
        vec![eval_config(&data, spec, AquaConfig::baseline(), "B (standard attn)", opt)?];
    for &r in ratios {
        let aqua = AquaConfig { k_ratio: r, ..Default::default() };
        rows.push(eval_config(&data, spec, aqua, &format!("k_ratio={r:.2}"), opt)?);
    }
    Ok(rows)
}

/// Table 2 / 5 — AQUA-H2O grid.
pub fn table2(
    arts: &Artifacts,
    spec: &BackendSpec,
    h2o_ratios: &[f64],
    k_ratios: &[f64],
    opt: &SweepOptions,
) -> Result<Vec<TableRow>> {
    let data = load_sweep_data(arts, opt)?;
    let mut rows = vec![];
    for &h in h2o_ratios {
        for &k in k_ratios {
            let aqua = AquaConfig { k_ratio: k, h2o_ratio: h, ..Default::default() };
            rows.push(eval_config(
                &data, spec, aqua,
                &format!("H2O={h:.2} k={k:.2}"),
                opt,
            )?);
        }
    }
    Ok(rows)
}

/// Table 3 / 6 — AQUA-Memory grid (static slice + dynamic top-k).
pub fn table3(
    arts: &Artifacts,
    spec: &BackendSpec,
    s_ratios: &[f64],
    k_ratios: &[f64],
    opt: &SweepOptions,
) -> Result<Vec<TableRow>> {
    let data = load_sweep_data(arts, opt)?;
    let mut rows =
        vec![eval_config(&data, spec, AquaConfig::baseline(), "Full Attn (E=1.000)", opt)?];
    for &s in s_ratios {
        for &k in k_ratios {
            let aqua = AquaConfig { k_ratio: k, s_ratio: s, ..Default::default() };
            rows.push(eval_config(
                &data, spec, aqua,
                &format!("S={s:.2} k={k:.2} E={:.3}", aqua.effective_ratio()),
                opt,
            )?);
        }
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Table 7 — qualitative generations vs k_ratio
// ---------------------------------------------------------------------------

pub fn table7(spec: &BackendSpec, prompt: &str, ratios: &[f64]) -> Result<Vec<(String, String)>> {
    use crate::coordinator::GenRequest;
    use crate::tokenizer::ByteTokenizer;
    let tok = ByteTokenizer;
    let mut out = vec![];
    let mut engine = Engine::with_spec(spec, EngineConfig { batch: 1, ..Default::default() })?;
    for &r in ratios {
        let label = if r >= 1.0 { "1.0 (baseline)".to_string() } else { format!("{r:.2}") };
        let aqua = if r >= 1.0 {
            AquaConfig::baseline()
        } else {
            AquaConfig { k_ratio: r, ..Default::default() }
        };
        engine.with_aqua(aqua);
        let mut req = GenRequest::new(1000 + (r * 100.0) as u64, tok.encode(prompt), 96);
        req.stop_token = Some(b'\n' as i32);
        let res = engine.run_batch(vec![req])?.remove(0);
        out.push((label, tok.decode(&res.tokens)));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Ablation — projection source (DESIGN.md "design choices")
// ---------------------------------------------------------------------------

pub struct AblationRow {
    pub source: String,
    pub series: Vec<(f64, f32)>,
}

/// The paper's P pools the GQA group's queries *and* the shared key
/// (§6.3); LoKi-style calibration uses keys only. This ablation builds P
/// from (a) keys only, (b) queries only, (c) the paper's combined stack —
/// each from the first half of the dump — and measures magnitude-selection
/// L_info on the *query* matrices of the held-out second half (queries are
/// what AQUA's selection reads, so misalignment shows up there).
#[cfg(feature = "pjrt")]
pub fn ablation_projection_source(arts: &Artifacts, model: &str) -> Result<Vec<AblationRow>> {
    let m = arts.model(model)?;
    let dump = load_dump(&m.calib_dump_npz)?;
    let gsz = m.config.group_size();

    let split = |t: &Tensor| -> (Tensor, Tensor) {
        let half = t.rows() / 2;
        let cols = t.cols();
        let a = Tensor::new(&[half, cols], t.data()[..half * cols].to_vec()).unwrap();
        let b = Tensor::new(&[t.rows() - half, cols], t.data()[half * cols..].to_vec()).unwrap();
        (a, b)
    };

    let k_t = dump.get("eval_l0_k").context("missing eval k")?;
    let (k_fit, _k_eval) = split(k_t);
    let mut q_fit_parts = vec![];
    let mut q_eval_parts = vec![];
    for j in 0..gsz {
        let q = dump.get(&format!("eval_l0_q{j}")).context("missing eval q")?;
        let (a, b) = split(q);
        q_fit_parts.push(a);
        q_eval_parts.push(b);
    }
    let q_fit_refs: Vec<&Tensor> = q_fit_parts.iter().collect();
    let q_fit = stack_rows(&q_fit_refs)?;
    let mut combined_refs: Vec<&Tensor> = q_fit_parts.iter().collect();
    combined_refs.push(&k_fit);
    let combined = stack_rows(&combined_refs)?;
    let q_eval_refs: Vec<&Tensor> = q_eval_parts.iter().collect();
    let eval_q = stack_rows(&q_eval_refs)?;

    let ratios = [0.125, 0.25, 0.5, 0.75];
    let mut rows = vec![];
    for (name, fit) in [
        ("keys only (LoKi-style)", &k_fit),
        ("queries only", &q_fit),
        ("queries+key combined (AQUA §6.3)", &combined),
    ] {
        let p = crate::tensor::svd::projection_from_data(fit)?;
        rows.push(AblationRow {
            source: name.to_string(),
            series: loss_series(&eval_q, &p, &ratios, Selection::ByMagnitude)?,
        });
    }
    Ok(rows)
}

pub fn print_ablation(rows: &[AblationRow]) {
    println!("# Ablation — projection calibration source (held-out query L_info, magnitude top-k)");
    print!("{:<38}", "P fitted on \\ k/d");
    for (r, _) in &rows[0].series {
        print!(" {r:>7.3}");
    }
    println!();
    for row in rows {
        print!("{:<38}", row.source);
        for (_, l) in &row.series {
            print!(" {l:>7.4}");
        }
        println!();
    }
}

// ---------------------------------------------------------------------------
// §5 break-even measurement
// ---------------------------------------------------------------------------

pub struct BreakevenRow {
    pub d: usize,
    pub k: usize,
    pub paper_bound: Option<usize>,
    pub measured_crossover: Option<usize>,
    /// Crossover of the dim-major *packed* kernel (keys already in the
    /// decode cache layout, as the native backend stores them).
    pub packed_crossover: Option<usize>,
}

/// Measure where the native sparse/packed AQUA scores (+ per-step query
/// projection and selection, via the zero-allocation kernel variants the
/// decode hot path uses) become cheaper than the dense baseline, vs the
/// paper's analytic bound.
pub fn breakeven(d_values: &[usize], k_fracs: &[f64], bencher: &Bencher) -> Vec<BreakevenRow> {
    use crate::aqua::native;
    use crate::tensor::topk::topk_indices_into;
    use crate::util::prng::Rng;
    let mut rng = Rng::new(99);
    let mut rows = vec![];
    for &d in d_values {
        let p: Vec<f32> = rng.normal_vec(d * d, (d as f32).powf(-0.5));
        for &kf in k_fracs {
            let k = ((kf * d as f64).round() as usize).clamp(1, d);
            let model = CostModel { d_head: d };
            let mut crossover = None;
            let mut packed_crossover = None;
            let mut qh = vec![0.0f32; d];
            let mut qsel = vec![0.0f32; d];
            let mut idx: Vec<usize> = Vec::with_capacity(d);
            let mut seq = 16usize;
            while seq <= 1 << 14 {
                let q: Vec<f32> = rng.normal_vec(d, 1.0);
                let keys: Vec<f32> = rng.normal_vec(seq * d, 1.0);
                // the same keys in the dim-major decode-cache layout
                // (transposed once here; the backend pays it at append)
                let mut kcols = vec![0.0f32; d * seq];
                for s in 0..seq {
                    for i in 0..d {
                        kcols[i * seq + s] = keys[s * d + i];
                    }
                }
                let mut out = vec![0.0f32; seq];
                let dense = bencher.run(&format!("dense d{d} s{seq}"), || {
                    native::dense_scores(&q, &keys, seq, d, &mut out);
                    crate::bench::black_box(&out);
                });
                if crossover.is_none() {
                    // per-step cost: project q, select, gather, sparse dot
                    let aqua = bencher.run(&format!("aqua d{d} k{k} s{seq}"), || {
                        native::project(&q, &p, d, &mut qh);
                        topk_indices_into(&qh, k, &mut idx);
                        for (j, &i) in idx.iter().enumerate() {
                            qsel[j] = qh[i];
                        }
                        native::aqua_scores_sparse_idx(&qsel[..k], &idx, &keys, seq, d, &mut out);
                        crate::bench::black_box(&out);
                    });
                    if aqua.mean_ns < dense.mean_ns {
                        crossover = Some(seq);
                    }
                }
                if packed_crossover.is_none() {
                    let packed = bencher.run(&format!("packed d{d} k{k} s{seq}"), || {
                        native::project(&q, &p, d, &mut qh);
                        topk_indices_into(&qh, k, &mut idx);
                        for (j, &i) in idx.iter().enumerate() {
                            qsel[j] = qh[i];
                        }
                        native::aqua_scores_packed_cols(
                            &qsel[..k], &idx, &kcols, seq, seq, &mut out,
                        );
                        crate::bench::black_box(&out);
                    });
                    if packed.mean_ns < dense.mean_ns {
                        packed_crossover = Some(seq);
                    }
                }
                if crossover.is_some() && packed_crossover.is_some() {
                    break;
                }
                seq *= 2;
            }
            rows.push(BreakevenRow {
                d,
                k,
                paper_bound: model.paper_breakeven(k),
                measured_crossover: crossover,
                packed_crossover,
            });
        }
    }
    rows
}

pub fn print_breakeven(rows: &[BreakevenRow]) {
    println!("# §5 break-even: AQUA vs standard scores (native kernels)");
    println!(
        "{:>6} {:>6} {:>16} {:>20} {:>20}",
        "d", "k", "paper i+1 bound", "sparse crossover", "packed crossover"
    );
    let show =
        |c: Option<usize>| c.map(|c| format!("<= {c}")).unwrap_or_else(|| "none<=16384".into());
    for r in rows {
        println!(
            "{:>6} {:>6} {:>16} {:>20} {:>20}",
            r.d,
            r.k,
            r.paper_bound.map(|b| b.to_string()).unwrap_or_else(|| "never".into()),
            show(r.measured_crossover),
            show(r.packed_crossover),
        );
    }
}
