//! Evaluation harness: WikiText-analog perplexity + SynthBench tasks,
//! scored exactly like the EleutherAI lm-evaluation-harness (MC by summed
//! continuation logprob, generation by greedy exact-match).

pub mod experiments;
pub mod ppl;
pub mod tasks;

pub use tasks::{EvalSummary, TaskItem, TaskSet};
