//! SynthBench task loading + scoring through the engine.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::{Engine, GenRequest};
use crate::tokenizer::ByteTokenizer;
use crate::util::json::Json;

/// One benchmark item.
#[derive(Debug, Clone)]
pub enum TaskItem {
    /// Multiple-choice: argmax over summed logprob of each choice
    /// continuation given the prompt.
    Mc { prompt: String, choices: Vec<String>, answer: usize },
    /// Greedy generation, exact match against target.
    Gen { prompt: String, target: String },
}

#[derive(Debug, Clone)]
pub struct TaskSet {
    pub name: String,
    /// Which paper benchmark this task stands in for (e.g. "MMLU").
    pub analog_of: String,
    pub items: Vec<TaskItem>,
}

impl TaskSet {
    pub fn load(name: &str, analog_of: &str, path: impl AsRef<Path>) -> Result<TaskSet> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading task file {:?}", path.as_ref()))?;
        let mut items = vec![];
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line).with_context(|| format!("task line {}", lineno + 1))?;
            match j.req_str("type")? {
                "mc" => {
                    let choices = j
                        .get("choices")
                        .as_arr()
                        .ok_or_else(|| anyhow::anyhow!("mc item missing choices"))?
                        .iter()
                        .map(|c| c.as_str().unwrap_or("").to_string())
                        .collect::<Vec<_>>();
                    items.push(TaskItem::Mc {
                        prompt: j.req_str("prompt")?.to_string(),
                        choices,
                        answer: j.req_i64("answer")? as usize,
                    });
                }
                "gen" => items.push(TaskItem::Gen {
                    prompt: j.req_str("prompt")?.to_string(),
                    target: j.req_str("target")?.to_string(),
                }),
                t => bail!("unknown task type '{t}'"),
            }
        }
        Ok(TaskSet { name: name.to_string(), analog_of: analog_of.to_string(), items })
    }

    pub fn truncated(mut self, n: usize) -> TaskSet {
        self.items.truncate(n);
        self
    }
}

/// Accuracy summary (mean ± standard error, the paper's format).
#[derive(Debug, Clone)]
pub struct EvalSummary {
    pub task: String,
    pub analog_of: String,
    pub n: usize,
    pub acc: f64,
    pub stderr: f64,
}

impl EvalSummary {
    fn from_hits(task: &str, analog: &str, hits: usize, n: usize) -> EvalSummary {
        let acc = hits as f64 / n.max(1) as f64;
        let stderr = if n > 1 { (acc * (1.0 - acc) / n as f64).sqrt() } else { 0.0 };
        EvalSummary { task: task.to_string(), analog_of: analog.to_string(), n, acc, stderr }
    }
}

/// Score a task set through the engine.
///
/// MC items submit one `score_only` request per choice (prompt+choice) and
/// compare the summed logprob over the choice's byte span. Gen items greedy
/// decode `target.len()+2` bytes and exact-match the prefix.
pub fn run_task(engine: &mut Engine, set: &TaskSet) -> Result<EvalSummary> {
    let tok = ByteTokenizer;
    let mut hits = 0usize;
    let mut next_id = 1u64;

    // Build all requests first so the continuous batcher can pack lanes.
    enum Pending {
        Mc { item: usize, choice: usize, prompt_len: usize },
        Gen { item: usize },
    }
    let mut reqs = vec![];
    let mut meta = vec![];
    for (i, item) in set.items.iter().enumerate() {
        match item {
            TaskItem::Mc { prompt, choices, .. } => {
                for (c, choice) in choices.iter().enumerate() {
                    let full = format!("{prompt}{choice}");
                    let ids = tok.encode(&full);
                    let mut r = GenRequest::new(next_id, ids, 0);
                    r.score_only = true;
                    next_id += 1;
                    meta.push(Pending::Mc { item: i, choice: c, prompt_len: prompt.len() });
                    reqs.push(r);
                }
            }
            TaskItem::Gen { prompt, target } => {
                let ids = tok.encode(prompt);
                let mut r = GenRequest::new(next_id, ids, target.len() + 2);
                r.stop_token = Some(b'\n' as i32);
                next_id += 1;
                meta.push(Pending::Gen { item: i });
                reqs.push(r);
            }
        }
    }

    let results = engine.run_batch(reqs)?;

    // Collate MC scores per item.
    let mut mc_scores: Vec<Vec<(usize, f64)>> = vec![vec![]; set.items.len()];
    for (res, m) in results.iter().zip(&meta) {
        match m {
            Pending::Mc { item, choice, prompt_len } => {
                // prompt_logprobs[t] is logP(prompt[t+1] | prefix); the
                // choice span starts at byte prompt_len, i.e. entries
                // prompt_len-1 .. end. Length-normalized (lm-eval acc_norm)
                // so shorter choices get no free ride.
                let start = prompt_len.saturating_sub(1).min(res.prompt_logprobs.len());
                let span = &res.prompt_logprobs[start..];
                // Requests rejected at admission (prompt+choice beyond the
                // backend's KV capacity) come back with no logprobs; score
                // them -inf so an oversized choice can never win argmax.
                let lp = if span.is_empty() {
                    f64::NEG_INFINITY
                } else {
                    span.iter().map(|&x| x as f64).sum::<f64>() / span.len() as f64
                };
                mc_scores[*item].push((*choice, lp));
            }
            Pending::Gen { item } => {
                if let TaskItem::Gen { target, .. } = &set.items[*item] {
                    let text = ByteTokenizer.decode(&res.tokens);
                    if text.starts_with(target.as_str()) {
                        hits += 1;
                    }
                }
            }
        }
    }
    for (i, item) in set.items.iter().enumerate() {
        if let TaskItem::Mc { answer, .. } = item {
            if mc_scores[i].is_empty() {
                continue;
            }
            let (best, best_lp) = *mc_scores[i]
                .iter()
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            // every choice rejected (capacity) -> scored as a miss
            if best_lp.is_finite() && best == *answer {
                hits += 1;
            }
        }
    }
    Ok(EvalSummary::from_hits(&set.name, &set.analog_of, hits, set.items.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_jsonl() {
        let dir = std::env::temp_dir().join(format!("aqua_tasks_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.jsonl");
        std::fs::write(
            &p,
            r#"{"type": "mc", "prompt": "the sky is", "choices": [" blue", " loud"], "answer": 0}
{"type": "gen", "prompt": "2 plus 2 equals", "target": " 4"}
"#,
        )
        .unwrap();
        let t = TaskSet::load("demo", "MMLU", &p).unwrap();
        assert_eq!(t.items.len(), 2);
        match &t.items[0] {
            TaskItem::Mc { choices, answer, .. } => {
                assert_eq!(choices.len(), 2);
                assert_eq!(*answer, 0);
            }
            _ => panic!("expected mc"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn summary_stderr() {
        let s = EvalSummary::from_hits("t", "X", 30, 60);
        assert!((s.acc - 0.5).abs() < 1e-12);
        assert!(s.stderr > 0.0 && s.stderr < 0.1);
    }
}
