//! Fleet serving quickstart: load `examples/fleet.json` (two AQUA
//! operating points of the same model), serve them behind one HTTP
//! router, route requests by name, then mutate the fleet at runtime
//! through the admin endpoints (`POST /models`, `DELETE /models/{name}`).
//!
//! ```bash
//! cargo run --release --example fleet
//! ```

use std::net::{SocketAddr, TcpListener};
use std::sync::Arc;

use anyhow::{Context, Result};

use aqua_serve::registry::ModelRegistry;
use aqua_serve::server;
use aqua_serve::server::http::client_request as http;
use aqua_serve::util::json::Json;

fn generate(addr: SocketAddr, model: Option<&str>, prompt: &str) -> Result<String> {
    let model_field = match model {
        Some(m) => format!(", \"model\": \"{m}\""),
        None => String::new(),
    };
    let body = format!("{{\"prompt\": \"{prompt}\", \"max_new_tokens\": 24{model_field}}}");
    let (status, resp) = http(addr, "POST", "/generate", &body)?;
    anyhow::ensure!(status == 200, "generate failed ({status}): {resp}");
    let doc = Json::parse(&resp)?;
    Ok(format!(
        "[{}] {:?} ({} tokens)",
        doc.get("model").as_str().unwrap_or("?"),
        doc.get("text").as_str().unwrap_or(""),
        doc.get("tokens").as_i64().unwrap_or(0)
    ))
}

fn main() -> Result<()> {
    // Fleet config lives next to this example; resolved relative to the
    // rust crate so the binary works from any CWD.
    let cfg_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/fleet.json");
    let text = std::fs::read_to_string(cfg_path).with_context(|| format!("reading {cfg_path}"))?;
    let doc = Json::parse(&text)?;
    let registry = Arc::new(ModelRegistry::from_fleet_json(&doc, aqua_serve::ARTIFACTS_DIR)?);
    println!("fleet: {} (default: {})", registry.names().join(", "),
             registry.default_name().unwrap_or_default());

    // Serve on an ephemeral loopback port, accept loop on its own thread.
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    {
        let registry = registry.clone();
        std::thread::spawn(move || {
            let _ = server::serve_on(listener, registry);
        });
    }
    println!("listening on http://{addr}\n");

    // --- route by name (and by fleet default) ---------------------------
    println!("{}", generate(addr, Some("exact"), "the capital of ")?);
    println!("{}", generate(addr, Some("pruned"), "the capital of ")?);
    println!("{} <- default routing", generate(addr, None, "the capital of ")?);

    // --- mutate the fleet at runtime ------------------------------------
    let spec = r#"{"name": "mid", "backend": "native", "k_ratio": 0.5, "batch": 2}"#;
    let (status, _) = http(addr, "POST", "/models", spec)?;
    anyhow::ensure!(status == 200, "POST /models failed ({status})");
    println!("\nadded 'mid' at runtime:");
    println!("{}", generate(addr, Some("mid"), "the capital of ")?);

    let (status, _) = http(addr, "DELETE", "/models/mid", "")?;
    anyhow::ensure!(status == 200, "DELETE /models/mid failed ({status})");
    let (status, _) = http(addr, "POST", "/generate", r#"{"prompt": "x", "model": "mid"}"#)?;
    anyhow::ensure!(status == 404, "deleted model should 404, got {status}");
    println!("removed 'mid' (drained; routing now 404s it)");

    // --- per-model metrics stay isolated --------------------------------
    let (status, resp) = http(addr, "GET", "/metrics", "")?;
    anyhow::ensure!(status == 200, "GET /metrics failed ({status})");
    let doc = Json::parse(&resp)?;
    println!("\nfleet requests_done = {}", doc.get("requests_done").as_i64().unwrap_or(0));
    for name in ["exact", "pruned"] {
        let m = doc.get("models").get(name);
        println!(
            "  {name:<7} requests={} kernels dense={} packed={} fused_passes={} queue_depth={} \
             shed={}",
            m.get("requests_done").as_i64().unwrap_or(0),
            m.get("kernel_dense").as_i64().unwrap_or(0),
            m.get("kernel_packed").as_i64().unwrap_or(0),
            m.get("kernel_fused_passes").as_i64().unwrap_or(0),
            m.get("queue_depth").as_i64().unwrap_or(0),
            m.get("shed_total").as_i64().unwrap_or(0)
        );
    }
    registry.shutdown_all()?;
    println!("\nfleet drained; bye");
    Ok(())
}
