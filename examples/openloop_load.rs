//! Open-loop load test: Poisson arrivals against the threaded engine
//! front-end (`EngineHandle`), the way a serving paper measures latency
//! under load — queueing delay included, unlike the closed-loop
//! serving_demo. The backend is constructed *on the engine thread* via
//! `BackendRecipe` (PJRT handles are !Send; the native model moves
//! freely).
//!
//! ```bash
//! cargo run --release --example openloop_load [-- <requests-per-second>...]
//! ```

use std::sync::mpsc;
use std::time::{Duration, Instant};

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::engine::{EngineCmd, EngineHandle};
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::runtime::{corpus_or_synthetic, default_spec};
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::util::prng::Rng;
use aqua_serve::util::{mean, percentile};

fn main() -> anyhow::Result<()> {
    let rates: Vec<f64> = {
        let args: Vec<f64> =
            std::env::args().skip(1).filter_map(|a| a.parse().ok()).collect();
        if args.is_empty() {
            vec![2.0, 6.0, 12.0]
        } else {
            args
        }
    };
    let spec = default_spec("llama-analog", 0)?;
    let backend_name = spec.name();
    // clamp prompts to the backend's KV capacity (requests generate 24)
    let max_prompt = spec.max_prompt(24);
    let corpus = corpus_or_synthetic(1 << 15);

    // Engine lives on its own thread; the recipe builds the backend there.
    let recipe = spec.recipe();
    let handle = EngineHandle::spawn(move || {
        Engine::new(
            recipe.build()?,
            EngineConfig {
                batch: 4,
                aqua: AquaConfig { k_ratio: 0.75, ..Default::default() },
                ..Default::default()
            },
        )
    });
    let tok = ByteTokenizer;
    let lines: Vec<&[u8]> = corpus.split(|&b| b == b'\n').filter(|l| l.len() > 10).collect();

    // Warm the backend (compiles executables on the pjrt path).
    handle.cmd_tx.send(EngineCmd::Submit(GenRequest::new(
        0,
        tok.encode_bytes(&lines[0][..lines[0].len().min(max_prompt)]),
        4,
    )))?;
    let _ = handle.result_rx.recv_timeout(Duration::from_secs(60));

    println!("# open-loop Poisson load, 20 requests per rate, AQUA k=0.75, batch=4, {backend_name} backend\n");
    println!("{:>8} {:>12} {:>12} {:>12} {:>10}",
             "req/s", "e2e p50", "e2e p99", "ttft p50", "done");
    let mut next_id = 1u64;
    for &rate in &rates {
        let n = 20usize;
        let mut rng = Rng::new(7);
        let mut submit_times = std::collections::HashMap::new();
        let t0 = Instant::now();
        let mut e2e = vec![];
        let mut ttft = vec![];
        let mut done = 0usize;
        let mut sent = 0usize;
        let mut next_arrival = Duration::ZERO;
        while done < n {
            // submit according to the Poisson schedule
            while sent < n && t0.elapsed() >= next_arrival {
                let line = lines[rng.below(lines.len())];
                let cut = (6 + rng.below(line.len() - 6)).min(max_prompt);
                let mut r = GenRequest::new(next_id, tok.encode_bytes(&line[..cut]), 24);
                r.stop_token = Some(b'\n' as i32);
                submit_times.insert(next_id, Instant::now());
                handle.cmd_tx.send(EngineCmd::Submit(r))?;
                next_id += 1;
                sent += 1;
                // exponential inter-arrival
                let u: f64 = rng.f64().max(1e-9);
                next_arrival += Duration::from_secs_f64(-u.ln() / rate);
            }
            match handle.result_rx.recv_timeout(Duration::from_millis(2)) {
                Ok(res) => {
                    let t_submit = submit_times[&res.id];
                    e2e.push(t_submit.elapsed().as_secs_f64() * 1e3);
                    ttft.push(res.ttft_us as f64 / 1e3);
                    done += 1;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(e) => anyhow::bail!("engine thread died: {e}"),
            }
        }
        println!("{:>8.1} {:>10.1}ms {:>10.1}ms {:>10.1}ms {:>10}",
                 rate, percentile(&e2e, 50.0), percentile(&e2e, 99.0),
                 percentile(&ttft, 50.0), done);
        let _ = mean(&e2e);
    }
    let _ = handle.cmd_tx.send(EngineCmd::Shutdown);
    Ok(())
}
