//! Open-loop load test over the multi-model registry: Poisson arrivals
//! split across two AQUA operating points (`exact` k=1.0 and `pruned`
//! k=0.25) behind bounded admission — the way a serving paper measures
//! latency under load, queueing delay *and* shed rate included, unlike
//! the closed-loop serving_demo. Each deployment's backend is
//! constructed on its own engine thread via `BackendRecipe`.
//!
//! Writes the per-model throughput/shed-rate trajectory to
//! `BENCH_serving.json` through `bench::report` (schema in BENCHES.md,
//! validated by `aqua benchcheck`).
//!
//! ```bash
//! cargo run --release --example openloop_load [-- [--abandon P] [--fault PLAN] <req/s>...]
//! ```
//!
//! `--abandon P` makes each accepted request a client hang-up candidate
//! with probability `P`: after a short sampled patience it is cancelled
//! mid-flight, exercising the lane-retire/KV-release path under load and
//! emitting `cancelled` / `abandon_rate` columns. `--fault PLAN` wraps
//! both deployments in the deterministic `fault:` backend (e.g.
//! `--fault err_every=40`), so injected step errors show up as `failed`
//! rows while the engines keep serving. `done` counts every resolved
//! admission (served + cancelled + failed), so the `done + shed == sent`
//! accounting the schema validator enforces still balances. `--trace MODE`
//! turns on each deployment's flight recorder; combined with `--fault` the
//! run asserts that the injected lane failures left postmortem snapshots.

use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use aqua_serve::bench::report::{serving_path, validate_serving, BenchReport};
use aqua_serve::coordinator::{FinishReason, GenRequest};
use aqua_serve::registry::{Admission, DeploymentSpec, ModelRegistry};
use aqua_serve::runtime::corpus_or_synthetic;
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::util::json::Json;
use aqua_serve::util::percentile;
use aqua_serve::util::prng::Rng;

/// Tokens each request generates (newline-stopped, so usually fewer).
const GEN_LEN: usize = 24;
/// Requests per arrival-rate point.
const REQUESTS_PER_RATE: usize = 24;

struct ModelLoad {
    name: &'static str,
    sent: u64,
    done: u64,
    shed: u64,
    cancelled: u64,
    failed: u64,
    tokens: u64,
    e2e_ms: Vec<f64>,
    ttft_ms: Vec<f64>,
    outstanding: Vec<u64>,
    submit_at: HashMap<u64, Instant>,
    /// Abandonment schedule: id → when the simulated client hangs up.
    abandon_at: HashMap<u64, Instant>,
}

impl ModelLoad {
    fn new(name: &'static str) -> ModelLoad {
        ModelLoad {
            name,
            sent: 0,
            done: 0,
            shed: 0,
            cancelled: 0,
            failed: 0,
            tokens: 0,
            e2e_ms: vec![],
            ttft_ms: vec![],
            outstanding: vec![],
            submit_at: HashMap::new(),
            abandon_at: HashMap::new(),
        }
    }
}

fn main() -> anyhow::Result<()> {
    let mut abandon_p = 0.0f64;
    let mut fault_plan: Option<String> = None;
    let mut trace_mode = "off".to_string();
    let mut rates: Vec<f64> = vec![];
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--abandon" => {
                abandon_p = args
                    .next()
                    .and_then(|v| v.parse().ok())
                    .ok_or_else(|| anyhow::anyhow!("--abandon needs a probability"))?;
            }
            "--trace" => {
                trace_mode = args
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("--trace needs off|errors|sampled:N|full"))?;
            }
            "--fault" => {
                // kv-specs split on commas, so fault params embed with `;`
                fault_plan = Some(
                    args.next()
                        .ok_or_else(|| anyhow::anyhow!("--fault needs a plan, e.g. err_every=40"))?
                        .replace(',', ";"),
                );
            }
            other => {
                if let Ok(r) = other.parse() {
                    rates.push(r);
                }
            }
        }
    }
    if rates.is_empty() {
        rates = vec![2.0, 6.0, 12.0];
    }

    // Two operating points of the same model behind one registry: the
    // exact baseline and an aggressive AQUA knob, queue-bounded at 8.
    // Under --fault both run behind the chaos wrapper with one restart
    // in budget, so an escalated failure heals instead of killing the run.
    let backend_kind = match &fault_plan {
        Some(plan) => format!("fault:native;{plan}"),
        None => "native".to_string(),
    };
    let lifecycle = if fault_plan.is_some() { ",restart=1,restart_backoff_ms=5" } else { "" };
    let registry = ModelRegistry::new(aqua_serve::ARTIFACTS_DIR);
    registry.deploy(DeploymentSpec::parse_kv(&format!(
        "name=exact,backend={backend_kind},k=1.0,batch=4,queue=8{lifecycle},trace={trace_mode}"
    ))?)?;
    registry.deploy(DeploymentSpec::parse_kv(&format!(
        "name=pruned,backend={backend_kind},k=0.25,batch=4,queue=8{lifecycle},trace={trace_mode}"
    ))?)?;
    // self-speculative decoding: drafts through the k=0.25 sparse path,
    // verifies exactly — output matches `exact`, throughput shouldn't
    registry.deploy(DeploymentSpec::parse_kv(&format!(
        "name=spec,backend={backend_kind},k=0.25,speculate=3,batch=4,queue=8{lifecycle},\
         trace={trace_mode}"
    ))?)?;
    let names: [&'static str; 3] = ["exact", "pruned", "spec"];
    let deps: Vec<_> = names.iter().map(|&n| registry.get(Some(n)).unwrap()).collect();
    let backend = deps[0].backend_kind();

    let corpus = corpus_or_synthetic(1 << 15);
    let tok = ByteTokenizer;
    let lines: Vec<&[u8]> = corpus.split(|&b| b == b'\n').filter(|l| l.len() > 10).collect();
    let max_prompt = deps[0].max_prompt(GEN_LEN);

    // Warm both engines (compiles executables on the pjrt path).
    for dep in &deps {
        let id = dep.fresh_id();
        let prompt = tok.encode_bytes(&lines[0][..lines[0].len().min(max_prompt)]);
        dep.submit(GenRequest::new(id, prompt, 4))?;
        let _ = dep.wait_result(id, Duration::from_secs(60));
    }

    println!(
        "# open-loop Poisson load, {REQUESTS_PER_RATE} requests per rate split over \
         {} models, queue=8, batch=4, {backend} backend, abandon_p={abandon_p}\n",
        names.len()
    );
    println!(
        "{:>8} {:>8} {:>6} {:>6} {:>6} {:>6} {:>6} {:>12} {:>12} {:>12} {:>12} {:>10} {:>8} {:>8}",
        "req/s", "model", "sent", "done", "shed", "cancel", "failed", "e2e p50", "e2e p99",
        "ttft p50", "ttft p99", "tok/s", "accept%", "eff t/s"
    );

    let mut rows: Vec<Json> = vec![];
    for &rate in &rates {
        // per-rate speculation ledger deltas (the deployments persist
        // across rate windows, so their counters accumulate)
        let pre: Vec<_> = deps.iter().map(|d| d.stats().unwrap()).collect();
        let mut rng = Rng::new(7);
        let mut loads: Vec<ModelLoad> = names.iter().map(|&n| ModelLoad::new(n)).collect();
        let t0 = Instant::now();
        let mut sent_total = 0usize;
        let mut next_arrival = Duration::ZERO;
        let mut last_progress = Instant::now();
        loop {
            let mut progressed = false;
            // submit according to the Poisson schedule, routing uniformly
            while sent_total < REQUESTS_PER_RATE && t0.elapsed() >= next_arrival {
                let m = rng.below(deps.len());
                let line = lines[rng.below(lines.len())];
                let cut = (6 + rng.below(line.len() - 6)).min(max_prompt);
                let id = deps[m].fresh_id();
                let mut r = GenRequest::new(id, tok.encode_bytes(&line[..cut]), GEN_LEN);
                r.stop_token = Some(b'\n' as i32);
                loads[m].sent += 1;
                match deps[m].submit(r)? {
                    Admission::Accepted => {
                        loads[m].submit_at.insert(id, Instant::now());
                        loads[m].outstanding.push(id);
                        // an impatient client: hangs up after a short
                        // sampled patience, cancelling mid-flight
                        if abandon_p > 0.0 && rng.f64() < abandon_p {
                            let patience = Duration::from_millis(1 + rng.below(24) as u64);
                            loads[m].abandon_at.insert(id, Instant::now() + patience);
                        }
                    }
                    Admission::Shed(_) => loads[m].shed += 1,
                }
                sent_total += 1;
                progressed = true;
                // exponential inter-arrival
                let u: f64 = rng.f64().max(1e-9);
                next_arrival += Duration::from_secs_f64(-u.ln() / rate);
            }
            // fire due abandonments (cancel is idempotent: a request that
            // already finished keeps its real result)
            for (m, dep) in deps.iter().enumerate() {
                let due: Vec<u64> = loads[m]
                    .abandon_at
                    .iter()
                    .filter(|(_, at)| Instant::now() >= **at)
                    .map(|(id, _)| *id)
                    .collect();
                for id in due {
                    loads[m].abandon_at.remove(&id);
                    dep.cancel(id);
                }
            }
            // drain completions — every resolved admission counts as done
            // (`done + shed == sent` stays the validator's identity), with
            // cancelled/failed outcomes tallied separately and only truly
            // served requests contributing latency samples
            for (m, dep) in deps.iter().enumerate() {
                let load = &mut loads[m];
                let ids = std::mem::take(&mut load.outstanding);
                for id in ids {
                    match dep.take_result(id) {
                        Some(res) => {
                            match res.finish {
                                FinishReason::Cancelled => load.cancelled += 1,
                                FinishReason::BackendError
                                | FinishReason::EngineFailed
                                | FinishReason::DeadlineExpired => load.failed += 1,
                                _ => {
                                    load.e2e_ms.push(
                                        load.submit_at[&id].elapsed().as_secs_f64() * 1e3,
                                    );
                                    // enqueue-relative TTFT from the engine's
                                    // own span clock, not the client's
                                    load.ttft_ms.push(res.timings.ttft_us as f64 / 1e3);
                                    load.tokens += res.tokens.len() as u64;
                                }
                            }
                            load.done += 1;
                            progressed = true;
                        }
                        None => load.outstanding.push(id),
                    }
                }
            }
            if sent_total >= REQUESTS_PER_RATE && loads.iter().all(|l| l.outstanding.is_empty()) {
                break;
            }
            if progressed {
                last_progress = Instant::now();
            } else if loads.iter().any(|l| !l.outstanding.is_empty())
                && last_progress.elapsed() > Duration::from_secs(60)
            {
                // the supervisor flushes terminal results even across
                // engine panics, so a long stall means something is truly
                // wedged — fail loudly, don't hang CI
                anyhow::bail!("open-loop drain made no progress for 60s — engine wedged?");
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        for (m, load) in loads.iter().enumerate() {
            // this window's draft ledger: counter deltas vs the pre-window
            // snapshot ("-" for deployments that never speculated)
            let post = deps[m].stats().unwrap();
            let drafted = post.spec_drafted - pre[m].spec_drafted;
            let accepted = post.spec_accepted - pre[m].spec_accepted;
            let committed = post.spec_committed - pre[m].spec_committed;
            let cycles = post.spec_lane_cycles - pre[m].spec_lane_cycles;
            let accept_rate = if drafted > 0 { accepted as f64 / drafted as f64 } else { 0.0 };
            let eff = if cycles > 0 { committed as f64 / cycles as f64 } else { 0.0 };
            let (accept_col, eff_col) = if cycles > 0 {
                (format!("{:.0}%", 100.0 * accept_rate), format!("{eff:.2}"))
            } else {
                ("-".into(), "-".into())
            };
            println!(
                "{:>8.1} {:>8} {:>6} {:>6} {:>6} {:>6} {:>6} {:>10.1}ms {:>10.1}ms {:>10.1}ms \
                 {:>10.1}ms {:>10.1} {:>8} {:>8}",
                rate,
                load.name,
                load.sent,
                load.done,
                load.shed,
                load.cancelled,
                load.failed,
                percentile(&load.e2e_ms, 50.0),
                percentile(&load.e2e_ms, 99.0),
                percentile(&load.ttft_ms, 50.0),
                percentile(&load.ttft_ms, 99.0),
                load.tokens as f64 / wall,
                accept_col,
                eff_col
            );
            rows.push(Json::obj(vec![
                ("model", Json::Str(load.name.to_string())),
                ("backend", Json::Str(backend.to_string())),
                ("rate_rps", Json::Num(rate)),
                ("sent", Json::Num(load.sent as f64)),
                ("done", Json::Num(load.done as f64)),
                ("shed", Json::Num(load.shed as f64)),
                (
                    "shed_rate",
                    Json::Num(if load.sent > 0 {
                        load.shed as f64 / load.sent as f64
                    } else {
                        0.0
                    }),
                ),
                ("cancelled", Json::Num(load.cancelled as f64)),
                (
                    "abandon_rate",
                    Json::Num(if load.sent > 0 {
                        load.cancelled as f64 / load.sent as f64
                    } else {
                        0.0
                    }),
                ),
                ("failed", Json::Num(load.failed as f64)),
                ("tok_per_s", Json::Num(load.tokens as f64 / wall)),
                ("e2e_p50_ms", Json::Num(percentile(&load.e2e_ms, 50.0))),
                ("e2e_p99_ms", Json::Num(percentile(&load.e2e_ms, 99.0))),
                ("ttft_p50_ms", Json::Num(percentile(&load.ttft_ms, 50.0))),
                ("ttft_p99_ms", Json::Num(percentile(&load.ttft_ms, 99.0))),
                ("spec_acceptance_rate", Json::Num(accept_rate)),
                ("tokens_per_step_effective", Json::Num(eff)),
            ]));
        }
    }
    // Under chaos with the flight recorder on, the injected lane failures
    // must have produced postmortem snapshots — the exact artifact an
    // operator would pull from /trace/postmortem after a real incident.
    if fault_plan.is_some() && trace_mode != "off" {
        let postmortems: usize = deps.iter().map(|d| d.trace().postmortems().len()).sum();
        anyhow::ensure!(
            postmortems > 0,
            "fault injection ran with trace={trace_mode} but no postmortem was captured"
        );
        println!("\n# captured {postmortems} postmortem snapshot(s) under fault injection");
    }
    registry.shutdown_all()?;

    let section = Json::obj(vec![
        ("rows", Json::Arr(rows)),
        ("model_cfg", Json::Str("llama-analog".to_string())),
        ("requests_per_rate", Json::Num(REQUESTS_PER_RATE as f64)),
        ("abandon_p", Json::Num(abandon_p)),
        ("fault", Json::Str(fault_plan.unwrap_or_default())),
        (
            "units",
            Json::Str(
                "open-loop Poisson; tok_per_s = generated tokens / rate-window wall; \
                 shed_rate = shed / sent at admission (queue bound 8); done counts every \
                 resolved admission incl. cancelled (client abandonment) and failed \
                 (injected backend faults); abandon_rate = cancelled / sent"
                    .to_string(),
            ),
        ),
    ]);
    let path = Path::new(serving_path());
    let mut rep = BenchReport::load_or_new(path);
    rep.set_section("openloop_serving", section);
    validate_serving(rep.doc(), false)?;
    rep.save(path)?;
    println!("\nwrote openloop_serving section to {}", path.display());
    Ok(())
}
