//! AQUA-H2O on long contexts: feed a long multi-fact prompt, sweep the H2O
//! budget, and show (a) the KV memory the eviction policy reclaims and
//! (b) that approximate-score-driven eviction keeps the answer intact at
//! moderate budgets (paper §8.3's synergy claim). Backend-generic; the
//! context length scales to the backend's KV capacity.

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::runtime::{corpus_or_synthetic, default_spec};
use aqua_serve::tokenizer::ByteTokenizer;

fn main() -> anyhow::Result<()> {
    let spec = default_spec("llama-analog", 0)?;
    let corpus = corpus_or_synthetic(1 << 14);
    let tok = ByteTokenizer;
    let (d, n_kv, n_layers, max_seq) = {
        let c = spec.model_config();
        (c.d_head, c.n_kv_heads, c.n_layers, c.max_seq)
    };
    let gen_len = 32usize;

    // Long context: as much corpus text as the KV capacity allows, then a
    // fresh fact query.
    let budget = max_seq.saturating_sub(gen_len + 20).max(16);
    let mut ctx: Vec<u8> = corpus[..budget.min(corpus.len())].to_vec();
    if let Some(nl) = ctx.iter().rposition(|&b| b == b'\n') {
        ctx.truncate(nl + 1);
    }
    ctx.extend_from_slice(b"the capital of ");
    let prompt = tok.encode_bytes(&ctx);
    println!("# longcontext_h2o — prompt {} bytes, generating {gen_len} ({} backend)\n",
             prompt.len(), spec.name());
    println!("{:>10} {:>8} {:>10} {:>12} {:>12}  generation",
             "h2o_ratio", "k_ratio", "evictions", "kv bytes", "kv saved");

    for (h, k) in [(1.0, 1.0), (0.75, 0.75), (0.5, 0.75), (0.25, 0.75), (0.25, 0.5)] {
        let aqua = AquaConfig { k_ratio: k, h2o_ratio: h, ..Default::default() };
        let mut engine = Engine::with_spec(
            &spec,
            EngineConfig { batch: 1, aqua, h2o_recent_window: 16, ..Default::default() },
        )?;
        let mut req = GenRequest::new(1, prompt.clone(), gen_len);
        req.stop_token = Some(b'\n' as i32);
        let res = engine.run_batch(vec![req])?.remove(0);
        let s = engine.metrics.snapshot();
        let total = prompt.len() + res.tokens.len();
        let per_slot = aqua.kv_bytes_per_slot(d, n_kv, n_layers);
        let full = total * per_slot;
        let live = full - (s.h2o_evictions as usize * per_slot);
        println!("{:>10.2} {:>8.2} {:>10} {:>12} {:>11.1}%  {:?}",
                 h, k, s.h2o_evictions, live,
                 100.0 * (full - live) as f64 / full as f64,
                 tok.decode(&res.tokens));
    }
    println!("\n(evicted slots return to the paged KV pool once their page drains; \
              per-slot bytes via AquaConfig::kv_bytes_per_slot == the pool's actual layout)");
    Ok(())
}
