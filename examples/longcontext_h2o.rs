//! AQUA-H2O on long contexts: feed a long multi-fact prompt, sweep the H2O
//! budget, and show (a) the KV memory the eviction policy reclaims and
//! (b) that approximate-score-driven eviction keeps the answer intact at
//! moderate budgets (paper §8.3's synergy claim).

use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::runtime::{Artifacts, ModelRuntime};
use aqua_serve::tokenizer::ByteTokenizer;

fn main() -> anyhow::Result<()> {
    let arts = Artifacts::load(aqua_serve::ARTIFACTS_DIR)?;
    let corpus = std::fs::read(arts.corpus_path("valid")?)?;
    let rt = Arc::new(ModelRuntime::load(arts.model("llama-analog")?)?);
    let tok = ByteTokenizer;
    let d = rt.cfg.d_head;
    let n_kv = rt.cfg.n_kv_heads;

    // Long context: ~380 bytes of corpus text, then a fresh fact query.
    let mut ctx: Vec<u8> = corpus[..380.min(corpus.len())].to_vec();
    if let Some(nl) = ctx.iter().rposition(|&b| b == b'\n') {
        ctx.truncate(nl + 1);
    }
    ctx.extend_from_slice(b"the capital of ");
    let prompt = tok.encode_bytes(&ctx);
    println!("# longcontext_h2o — prompt {} bytes, generating 32\n", prompt.len());
    println!("{:>10} {:>8} {:>10} {:>12} {:>12}  generation",
             "h2o_ratio", "k_ratio", "evictions", "kv bytes", "kv saved");

    for (h, k) in [(1.0, 1.0), (0.75, 0.75), (0.5, 0.75), (0.25, 0.75), (0.25, 0.5)] {
        let aqua = AquaConfig { k_ratio: k, h2o_ratio: h, ..Default::default() };
        let mut engine = Engine::new(
            rt.clone(),
            EngineConfig { batch: 1, aqua, h2o_recent_window: 16, ..Default::default() },
        )?;
        let mut req = GenRequest::new(1, prompt.clone(), 32);
        req.stop_token = Some(b'\n' as i32);
        let res = engine.run_batch(vec![req])?.remove(0);
        let s = engine.metrics.snapshot();
        let total = prompt.len() + res.tokens.len();
        let per_slot = aqua.kv_bytes_per_slot(d, n_kv);
        let full = total * per_slot;
        let live = full - (s.h2o_evictions as usize * per_slot);
        println!("{:>10.2} {:>8.2} {:>10} {:>12} {:>11.1}%  {:?}",
                 h, k, s.h2o_evictions, live,
                 100.0 * (full - live) as f64 / full as f64,
                 tok.decode(&res.tokens));
    }
    println!("\n(evicted slots are reclaimable pages; bytes computed via AquaConfig::kv_bytes_per_slot)");
    Ok(())
}
