//! End-to-end serving driver (the repo's headline validation run).
//!
//! Serves a batched workload of prompts through the full stack (admission
//! → continuous batching → prefill/decode → sampling) and reports
//! latency/throughput at several AQUA operating points. Backend-generic:
//! the hermetic native backend by default, the in-repo-trained PJRT model
//! when built with `--features pjrt` after `make artifacts`. Results are
//! recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example serving_demo [-- <n_requests>]
//! ```

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::runtime::{corpus_or_synthetic, default_spec};
use aqua_serve::tokenizer::ByteTokenizer;
use aqua_serve::util::prng::Rng;

const GEN_LEN: usize = 48;

/// Shared system-prompt header every request carries (multi-turn fleets
/// look like this) — with the prefix cache on, one prefill's pages serve
/// every lane, and the hit-rate column below shows how much prompt work
/// that skipped.
const PREAMBLE: &[u8] = b"system: answer with one short factual phrase. ";

/// Prompts clamped to the backend's KV capacity, so a real-corpus line
/// never turns into a silent PromptTooLong reject on the tiny native model.
fn workload(corpus: &[u8], n: usize, max_prompt: usize, rng: &mut Rng) -> Vec<GenRequest> {
    let tok = ByteTokenizer;
    let lines: Vec<&[u8]> = corpus.split(|&b| b == b'\n').filter(|l| l.len() > 8).collect();
    (0..n)
        .map(|i| {
            // prompt = shared preamble + a corpus line prefix
            let line = lines[rng.below(lines.len())];
            let cut = (4 + rng.below(line.len() - 4)).min(max_prompt - PREAMBLE.len());
            let mut prompt = PREAMBLE.to_vec();
            prompt.extend_from_slice(&line[..cut]);
            let mut r = GenRequest::new(i as u64 + 1, tok.encode_bytes(&prompt), GEN_LEN);
            r.stop_token = Some(b'\n' as i32);
            r
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(24);
    let spec = default_spec("llama-analog", 0)?;
    let corpus = corpus_or_synthetic(1 << 15);
    let max_prompt = spec.max_prompt(GEN_LEN);

    // Warm the backend (compiles the prefill/decode executables on the
    // pjrt path) so the first operating point pays no one-time cost.
    {
        let mut warm = Engine::with_spec(&spec, EngineConfig { batch: 4, ..Default::default() })?;
        let mut rng = Rng::new(1);
        warm.run_batch(workload(&corpus, 4, max_prompt, &mut rng))?;
    }

    println!("# serving_demo — {n} batched requests per operating point (batch=4, {} backend, \
              prefix cache on)\n",
             spec.name());
    println!("{:<34} {:>10} {:>12} {:>12} {:>12} {:>10} {:>12} {:>8} {:>8} {:>8} {:>22}",
             "operating point", "tok/s", "ttft p50", "ttft p99", "lat mean", "evictions",
             "kv peak", "prefix%", "accept%", "eff t/s", "kernels (d/s/p)");
    for (label, aqua, speculate) in [
        ("baseline (standard attention)", AquaConfig::baseline(), 0usize),
        ("AQUA k=0.75", AquaConfig { k_ratio: 0.75, ..Default::default() }, 0),
        ("AQUA k=0.50", AquaConfig { k_ratio: 0.50, ..Default::default() }, 0),
        ("AQUA-H2O k=0.75 h2o=0.50",
         AquaConfig { k_ratio: 0.75, h2o_ratio: 0.5, ..Default::default() }, 0),
        ("AQUA-Memory S=0.10 k=0.90",
         AquaConfig { k_ratio: 0.90, s_ratio: 0.10, ..Default::default() }, 0),
        // self-speculative decoding: AQUA-sparse draft, exact verify over
        // the same KV — output stays bit-identical to the baseline row
        ("AQUA-spec k=0.25 speculate=4",
         AquaConfig { k_ratio: 0.25, ..Default::default() }, 4),
        ("AQUA-spec k=0.50 speculate=2",
         AquaConfig { k_ratio: 0.50, ..Default::default() }, 2),
    ] {
        let mut engine = Engine::with_spec(
            &spec,
            EngineConfig { batch: 4, aqua, speculate, prefix_cache: true, ..Default::default() },
        )?;
        let mut rng = Rng::new(42);
        let reqs = workload(&corpus, n, max_prompt, &mut rng);
        let t0 = std::time::Instant::now();
        let results = engine.run_batch(reqs)?;
        let wall = t0.elapsed().as_secs_f64();
        let s = engine.metrics.snapshot();
        let total_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        // which score kernel actually ran at this operating point
        // (dense/sparse/packed head-calls, see runtime::KernelCounters),
        // the peak resident KV of the paged pool — actual leased pages,
        // not the cost model (AQUA-Memory points shrink it) — and the
        // prefix-cache hit rate (the shared preamble's pages attach
        // instead of re-prefilling; H2O points share nothing by design)
        let kern = format!("{}/{}/{}", s.kernels.dense, s.kernels.sparse, s.kernels.packed);
        let kv_peak = format!("{:.1}KiB", s.kv_resident_peak_bytes as f64 / 1024.0);
        let hits = format!("{:.0}%", 100.0 * s.prefix_hit_rate());
        // draft acceptance and committed-tokens-per-verify-cycle, when
        // this operating point speculates ("-" on plain-decode rows)
        let (accept, eff) = if s.spec_lane_cycles > 0 {
            (format!("{:.0}%", 100.0 * s.spec_acceptance_rate),
             format!("{:.2}", s.tokens_per_step_effective))
        } else {
            ("-".into(), "-".into())
        };
        println!("{:<34} {:>10.1} {:>10.1}ms {:>10.1}ms {:>10.1}ms {:>10} {:>12} {:>8} {:>8} \
                  {:>8} {:>22}",
                 label, total_tokens as f64 / wall, s.p50_ttft_ms, s.p99_ttft_ms,
                 s.mean_latency_ms, s.h2o_evictions, kv_peak, hits, accept, eff, kern);
    }
    println!("\n(swap in the PJRT model via --features pjrt + make artifacts; see DESIGN.md)");
    Ok(())
}
