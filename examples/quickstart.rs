//! Quickstart: build a backend, generate text, flip the AQUA knob.
//!
//! Hermetic by default (native backend, seeded weights); picks up the
//! PJRT artifacts when built with `--features pjrt` after `make artifacts`.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::runtime::{default_backend, ExecBackend};
use aqua_serve::tokenizer::ByteTokenizer;

fn main() -> anyhow::Result<()> {
    let backend = default_backend("llama-analog", 0)?;
    let tok = ByteTokenizer;

    let mut engine = Engine::new(backend, EngineConfig { batch: 1, ..Default::default() })?;
    println!("backend: {}\n", engine.backend().name());

    let prompt = "the capital of ";
    println!("prompt: {prompt:?}\n");
    for (label, aqua) in [
        ("standard attention (baseline)", AquaConfig::baseline()),
        ("AQUA k_ratio=0.75 (the paper's sweet spot)",
         AquaConfig { k_ratio: 0.75, ..Default::default() }),
        ("AQUA k_ratio=0.30 (aggressive, quality degrades)",
         AquaConfig { k_ratio: 0.30, ..Default::default() }),
    ] {
        engine.with_aqua(aqua);
        let mut req = GenRequest::new(1, tok.encode(prompt), 48);
        req.stop_token = Some(b'\n' as i32);
        let res = engine.run_batch(vec![req])?.remove(0);
        println!("{label}\n  -> {:?}", tok.decode(&res.tokens));
        let d = engine.model_config().d_head;
        println!("  k = {}/{} dims, effective ratio {:.2}\n",
                 aqua.k_dims(d), d, aqua.effective_ratio());
    }
    println!("{}", engine.metrics.snapshot().report());
    Ok(())
}
