//! Quickstart: load the artifacts, generate text, flip the AQUA knob.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use aqua_serve::aqua::policy::AquaConfig;
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::runtime::{Artifacts, ModelRuntime};
use aqua_serve::tokenizer::ByteTokenizer;

fn main() -> anyhow::Result<()> {
    let arts = Artifacts::load(aqua_serve::ARTIFACTS_DIR)?;
    let rt = Arc::new(ModelRuntime::load(arts.model("llama-analog")?)?);
    let tok = ByteTokenizer;

    let mut engine = Engine::new(rt, EngineConfig { batch: 1, ..Default::default() })?;

    let prompt = "the capital of ";
    println!("prompt: {prompt:?}\n");
    for (label, aqua) in [
        ("standard attention (baseline)", AquaConfig::baseline()),
        ("AQUA k_ratio=0.75 (the paper's sweet spot)",
         AquaConfig { k_ratio: 0.75, ..Default::default() }),
        ("AQUA k_ratio=0.30 (aggressive, quality degrades)",
         AquaConfig { k_ratio: 0.30, ..Default::default() }),
    ] {
        engine.with_aqua(aqua);
        let mut req = GenRequest::new(1, tok.encode(prompt), 48);
        req.stop_token = Some(b'\n' as i32);
        let res = engine.run_batch(vec![req])?.remove(0);
        println!("{label}\n  -> {:?}", tok.decode(&res.tokens));
        let d = engine.runtime().cfg.d_head;
        println!("  k = {}/{} dims, effective ratio {:.2}\n",
                 aqua.k_dims(d), d, aqua.effective_ratio());
    }
    println!("{}", engine.metrics.snapshot().report());
    Ok(())
}
