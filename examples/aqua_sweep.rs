//! The controllable knob: sweep k_ratio (and the AQUA-Memory slice) on a
//! fixed prompt and show the quality/cost/memory trade-off (paper Table
//! 7's qualitative story + the §5 cost model + measured resident KV side
//! by side). Backend-generic — runs hermetically on the native backend
//! without artifacts.

use aqua_serve::aqua::policy::{AquaConfig, CostModel};
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::runtime::default_spec;
use aqua_serve::tokenizer::ByteTokenizer;

fn main() -> anyhow::Result<()> {
    let spec = default_spec("llama-analog", 0)?;
    let d = spec.model_config().d_head;
    let cost = CostModel { d_head: d };
    let tok = ByteTokenizer;

    let prompt = "the capital of ";
    println!("# AQUA knob sweep — prompt {prompt:?} (greedy, {} backend)\n",
             spec.name());
    println!("{:>8} {:>8} {:>5} {:>14} {:>16} {:>12}  generation",
             "k_ratio", "kv_keep", "k", "score FLOPs@512", "break-even i+1", "kv peak");
    // (k_ratio, s_ratio) points: the compute sweep at full memory, then
    // AQUA-Memory points showing the resident-KV axis shrink
    let points = [(1.0, 0.0), (0.9, 0.0), (0.75, 0.0), (0.5, 0.0), (0.4, 0.0), (0.3, 0.0),
                  (0.2, 0.0), (0.1, 0.0), (1.0, 0.25), (1.0, 0.5)];
    for (r, s_ratio) in points {
        let aqua = if r >= 1.0 && s_ratio == 0.0 {
            AquaConfig::baseline()
        } else {
            AquaConfig { k_ratio: r, s_ratio, ..Default::default() }
        };
        // fresh engine per point (model weights shared through the spec):
        // the kv-peak column then reports this point's pool, and s_ratio
        // points get their truncated-key page layout from construction
        let mut engine =
            Engine::with_spec(&spec, EngineConfig { batch: 1, aqua, ..Default::default() })?;
        let mut req = GenRequest::new(1, tok.encode(prompt), 40);
        req.stop_token = Some(b'\n' as i32);
        let res = engine.run_batch(vec![req])?.remove(0);
        let k = aqua.k_dims(d);
        let flops = if r >= 1.0 && s_ratio == 0.0 {
            cost.standard_flops(512)
        } else {
            cost.aqua_flops(512, k)
        };
        let be = cost
            .paper_breakeven(k)
            .map(|b| b.to_string())
            .unwrap_or_else(|| "never".into());
        // measured resident KV bytes of the paged pool at this operating
        // point (peak over the run) — the memory axis of the sweep
        let kv = engine.metrics.snapshot().kv_resident_peak_bytes;
        println!("{:>8.2} {:>8.2} {:>5} {:>14} {:>16} {:>11.1}K  {:?}",
                 r, 1.0 - s_ratio, k, flops, be, kv as f64 / 1024.0, tok.decode(&res.tokens));
    }
    Ok(())
}
