//! The controllable knob: sweep k_ratio on a fixed prompt and show the
//! quality/cost trade-off (paper Table 7's qualitative story + the §5 cost
//! model side by side). Backend-generic — runs hermetically on the native
//! backend without artifacts.

use aqua_serve::aqua::policy::{AquaConfig, CostModel};
use aqua_serve::coordinator::{Engine, EngineConfig, GenRequest};
use aqua_serve::runtime::{default_backend, ExecBackend};
use aqua_serve::tokenizer::ByteTokenizer;

fn main() -> anyhow::Result<()> {
    let backend = default_backend("llama-analog", 0)?;
    let d = backend.model_config().d_head;
    let cost = CostModel { d_head: d };
    let tok = ByteTokenizer;
    let mut engine = Engine::new(backend, EngineConfig { batch: 1, ..Default::default() })?;

    let prompt = "the capital of ";
    println!("# AQUA knob sweep — prompt {prompt:?} (greedy, {} backend)\n",
             engine.backend().name());
    println!("{:>8} {:>5} {:>14} {:>16}  generation",
             "k_ratio", "k", "score FLOPs@512", "break-even i+1");
    for r in [1.0, 0.9, 0.75, 0.5, 0.4, 0.3, 0.2, 0.1] {
        let aqua = if r >= 1.0 {
            AquaConfig::baseline()
        } else {
            AquaConfig { k_ratio: r, ..Default::default() }
        };
        engine.with_aqua(aqua);
        let mut req = GenRequest::new(1, tok.encode(prompt), 40);
        req.stop_token = Some(b'\n' as i32);
        let res = engine.run_batch(vec![req])?.remove(0);
        let k = aqua.k_dims(d);
        let flops = if r >= 1.0 { cost.standard_flops(512) } else { cost.aqua_flops(512, k) };
        let be = cost
            .paper_breakeven(k)
            .map(|b| b.to_string())
            .unwrap_or_else(|| "never".into());
        println!("{:>8.2} {:>5} {:>14} {:>16}  {:?}",
                 r, k, flops, be, tok.decode(&res.tokens));
    }
    Ok(())
}
