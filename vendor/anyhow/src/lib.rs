//! Hermetic stand-in for the `anyhow` crate.
//!
//! The offline build environment has no crate registry, so this in-tree
//! path dependency provides the subset of the `anyhow` API the workspace
//! uses: `Error` with a context chain, `Result<T>`, the `anyhow!`/`bail!`/
//! `ensure!` macros, and the `Context` extension trait for `Result` and
//! `Option`. Formatting matches anyhow's conventions: `{}` prints the
//! outermost message, `{:#}` prints the whole chain joined by `: `, and
//! `{:?}` prints the chain as a `Caused by:` list.

use std::fmt;

/// An error with a chain of context messages (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an additional outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` legal. Any
// std-error (io, parse, utf8, ...) converts via `?`, capturing its source
// chain as context.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn display_and_chain() {
        let e: Error = Err::<(), _>(io_err()).context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: missing");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_work() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big");
        let e = anyhow!("custom {}", 42);
        assert_eq!(e.to_string(), "custom 42");
    }

    #[test]
    fn option_context() {
        let none: Option<i32> = None;
        let e = none.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn question_mark_from_std_error() {
        fn f() -> Result<i32> {
            let n: i32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(f().unwrap(), 12);
    }
}
