//! API-surface stub of the `xla` (xla_extension) binding.
//!
//! The production PJRT path (`--features pjrt`) compiles against this
//! in-tree stub so the whole workspace builds offline with no registry or
//! C++ binary download. Host-side `Literal` operations are implemented for
//! real (the PJRT wrappers in `runtime::exec` are unit-tested against
//! them); everything that would touch an actual PJRT client or parse npz
//! files returns a descriptive error at runtime. To serve against real
//! AOT-compiled executables, point the `xla` path dependency in
//! `rust/Cargo.toml` at the real `xla` crate — the API subset used by this
//! repo matches it.

use std::path::Path;

/// Stub error; formats with enough context to explain itself.
#[derive(Debug, Clone)]
pub struct Error(pub String);

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} is not available in the hermetic xla stub; link the real \
         xla_extension binding (see vendor/xla/src/lib.rs) to use PJRT"
    ))
}

// ---------------------------------------------------------------------------
// Element types
// ---------------------------------------------------------------------------

// `non_exhaustive` mirrors the real binding's larger dtype set, and keeps
// downstream wildcard match arms from tripping unreachable-pattern lints.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum ElementType {
    F32,
    S32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[non_exhaustive]
pub enum PrimitiveType {
    F32,
    S32,
}

impl ElementType {
    pub fn primitive_type(self) -> PrimitiveType {
        match self {
            ElementType::F32 => PrimitiveType::F32,
            ElementType::S32 => PrimitiveType::S32,
        }
    }
}

/// Typed element storage (public so `NativeType` can name it; not part of
/// the real xla API, which hides this behind C++).
#[doc(hidden)]
#[derive(Debug, Clone)]
pub enum Store {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Rust scalar types a `Literal` can hold.
pub trait NativeType: Copy {
    const TY: ElementType;
    #[doc(hidden)]
    fn to_store(v: Vec<Self>) -> Store;
    #[doc(hidden)]
    fn from_store(s: &Store) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn to_store(v: Vec<Self>) -> Store {
        Store::F32(v)
    }
    fn from_store(s: &Store) -> Option<Vec<Self>> {
        match s {
            Store::F32(v) => Some(v.clone()),
            Store::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn to_store(v: Vec<Self>) -> Store {
        Store::I32(v)
    }
    fn from_store(s: &Store) -> Option<Vec<Self>> {
        match s {
            Store::I32(v) => Some(v.clone()),
            Store::F32(_) => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Shapes and literals (host-side: implemented for real)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    store: Store,
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], store: T::to_store(data.to_vec()) }
    }

    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], store: T::to_store(vec![v]) }
    }

    fn len(&self) -> usize {
        match &self.store {
            Store::F32(v) => v.len(),
            Store::I32(v) => v.len(),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.len() {
            return Err(Error(format!(
                "reshape to {dims:?} ({n} elems) from {} elems",
                self.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), store: self.store.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        let ty = match &self.store {
            Store::F32(_) => ElementType::F32,
            Store::I32(_) => ElementType::S32,
        };
        Ok(ArrayShape { dims: self.dims.clone(), ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::from_store(&self.store)
            .ok_or_else(|| Error(format!("to_vec: literal holds {:?}", self.array_shape())))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error("stub literals are never tuples (tuples only come from PJRT execution)".into()))
    }

    pub fn convert(&self, ty: PrimitiveType) -> Result<Literal, Error> {
        let store = match (&self.store, ty) {
            (Store::F32(v), PrimitiveType::F32) => Store::F32(v.clone()),
            (Store::I32(v), PrimitiveType::S32) => Store::I32(v.clone()),
            (Store::I32(v), PrimitiveType::F32) => Store::F32(v.iter().map(|&x| x as f32).collect()),
            (Store::F32(v), PrimitiveType::S32) => Store::I32(v.iter().map(|&x| x as i32).collect()),
        };
        Ok(Literal { dims: self.dims.clone(), store })
    }
}

/// npz deserialization entry points (real binding reads numpy archives;
/// the stub has no npz parser and errors out).
pub trait FromRawBytes: Sized {
    fn read_npz<P: AsRef<Path>, O>(path: P, opts: &O) -> Result<Vec<(String, Self)>, Error>;
    fn read_npz_by_name<P: AsRef<Path>, O>(
        path: P,
        opts: &O,
        names: &[&str],
    ) -> Result<Vec<Self>, Error>;
}

impl FromRawBytes for Literal {
    fn read_npz<P: AsRef<Path>, O>(path: P, _opts: &O) -> Result<Vec<(String, Self)>, Error> {
        Err(unavailable(&format!("read_npz({:?})", path.as_ref())))
    }

    fn read_npz_by_name<P: AsRef<Path>, O>(
        path: P,
        _opts: &O,
        _names: &[&str],
    ) -> Result<Vec<Self>, Error> {
        Err(unavailable(&format!("read_npz_by_name({:?})", path.as_ref())))
    }
}

// ---------------------------------------------------------------------------
// PJRT surface (stubbed: constructors fail, so methods are unreachable)
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct PjRtClient(());

#[derive(Debug)]
pub struct PjRtBuffer(());

#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

#[derive(Debug)]
pub struct HloModuleProto(());

#[derive(Debug)]
pub struct XlaComputation(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        Err(unavailable("buffer_from_host_buffer"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto, Error> {
        Err(unavailable(&format!("HloModuleProto::from_text_file({:?})", path.as_ref())))
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let s = l.array_shape().unwrap();
        assert_eq!(s.dims(), &[2, 2]);
        assert_eq!(s.ty(), ElementType::F32);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn convert_casts() {
        let l = Literal::vec1(&[1i32, 2]);
        let f = l.convert(PrimitiveType::F32).unwrap();
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn pjrt_is_stubbed() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("/x").is_err());
    }
}
