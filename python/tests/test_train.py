"""Training substrate: optimizer step math, loss decreases on a tiny run."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import train as TR
from compile.config import TrainConfig


def test_byte_dataset_windows():
    data = bytes(range(256)) * 4
    ds = TR.ByteDataset(data, seq=16, seed=0)
    b = ds.batch(3)
    assert b.shape == (3, 17)
    assert b.min() >= 0 and b.max() < 256


def test_adam_moves_params_downhill():
    tc = TrainConfig(lr=0.1, warmup=1, steps=10, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = TR.adam_init(params)
    # Adam's normalized update moves ~lr per step; 80 steps at lr=0.1
    # must bring |w|∞=5 near the optimum at 0.
    for step in range(80):
        grads = {"w": 2 * params["w"]}  # d/dw of w²
        params, state = TR.adam_update(tc, params, grads, state, 0.1)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_grad_clip_bounds_update():
    tc = TrainConfig(grad_clip=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = TR.adam_init(params)
    grads = {"w": jnp.full(4, 1e6)}
    new, _ = TR.adam_update(tc, params, grads, state, 1.0)
    assert float(jnp.abs(new["w"]).max()) < 2.0  # clipped, not 1e6·lr


def test_lr_schedule_shape():
    tc = TrainConfig(steps=100, warmup=10, lr=1.0, lr_min_frac=0.1)
    lrs = [float(TR.lr_at(tc, s)) for s in range(100)]
    assert lrs[0] < lrs[9]            # warmup rising
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] < lrs[20]          # decays
    assert lrs[-1] >= 0.099           # floor


def test_tiny_training_reduces_loss(small_cfg):
    tc = TrainConfig(steps=25, batch=4, eval_every=24, eval_batches=1, seed=1)
    rng = np.random.default_rng(0)
    # learnable structure: repeating pattern
    data = (b"abcdefgh" * 800)
    logs = []
    params, curve = TR.train(small_cfg, tc, data, data, log=lambda m: logs.append(m))
    assert curve[0]["train_loss"] > curve[-1]["valid_loss"]
    assert curve[-1]["valid_loss"] < 2.5  # pattern is easy


def test_params_npz_roundtrip(tmp_path, small_cfg):
    params = M.init_params(small_cfg, jax.random.PRNGKey(0))
    p = str(tmp_path / "p.npz")
    TR.save_params(params, p)
    loaded = TR.load_params(p)
    assert set(loaded) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(loaded[k]))
