"""Calibration pipeline: GQA stacking, SVD projection properties."""

import jax
import numpy as np
import pytest

from compile import calibrate as C
from compile import model as M
from compile.config import CalibConfig


@pytest.fixture(scope="module")
def tiny_calib(small_cfg):
    params = M.init_params(small_cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    data = bytes(rng.integers(32, 127, size=4000, dtype=np.uint8))
    cc = CalibConfig(batches=2, batch=2, seq=24, max_vectors_per_group=256,
                     dump_vectors=64)
    return small_cfg, params, data, cc


def test_collect_shapes(tiny_calib):
    cfg, params, data, cc = tiny_calib
    qs, ks = C.collect_activations(cfg, params, data, cc)
    assert len(qs) == cfg.n_layers
    assert qs[0].shape[1:] == (cfg.n_q_heads, cfg.d_head)
    assert ks[0].shape[1:] == (cfg.n_kv_heads, cfg.d_head)
    assert qs[0].shape[0] <= cc.max_vectors_per_group


def test_gqa_stack_shape(tiny_calib):
    cfg, params, data, cc = tiny_calib
    qs, ks = C.collect_activations(cfg, params, data, cc)
    d = C.gqa_stack(cfg, qs[0], ks[0], 0)
    n = qs[0].shape[0]
    # N_Q query matrices + 1 key matrix stacked vertically (paper §6.3)
    assert d.shape == ((cfg.group_size + 1) * n, cfg.d_head)


def test_projection_orthogonal_and_variance_ordered(tiny_calib):
    cfg, params, data, cc = tiny_calib
    proj, _ = C.calibrate(cfg, params, data, cc)
    assert proj.shape == (cfg.n_layers, cfg.n_kv_heads, cfg.d_head, cfg.d_head)
    for l in range(cfg.n_layers):
        for g in range(cfg.n_kv_heads):
            p = proj[l, g]
            np.testing.assert_allclose(p.T @ p, np.eye(cfg.d_head), atol=1e-4)


def test_projected_variance_decreasing(tiny_calib):
    """Columns of P must order projected variance decreasingly (that's what
    makes the AQUA-Memory static slice of *trailing* dims principled)."""
    cfg, params, data, cc = tiny_calib
    qs, ks = C.collect_activations(cfg, params, data, cc)
    d_calib = C.gqa_stack(cfg, qs[0], ks[0], 0)
    p = C.svd_projection(d_calib)
    var = ((d_calib @ p) ** 2).sum(axis=0)
    assert np.all(var[:-1] >= var[1:] - 1e-2 * var[0])


def test_svd_projection_matches_numpy_pca():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(200, 8)).astype(np.float32)
    x[:, 0] *= 10  # dominant direction
    p = C.svd_projection(x)
    # first principal direction ≈ e0
    assert abs(p[0, 0]) > 0.99
