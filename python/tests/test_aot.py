"""AOT artifacts: HLO lowering works on a tiny config; the real artifacts
(when built) are structurally sound and numerically match the python model."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import to_hlo_text


def test_tiny_decode_lowers_to_hlo_text(small_cfg):
    cfg = small_cfg
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    plist = M.params_to_list(params)
    pspecs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in plist]
    b, d, L, nkv, S = 1, cfg.d_head, cfg.n_layers, cfg.n_kv_heads, cfg.max_seq
    f32, i32 = jnp.float32, jnp.int32
    cache = jax.ShapeDtypeStruct((L, b, S, nkv, d), f32)
    specs = pspecs + [
        jax.ShapeDtypeStruct((L, nkv, d, d), f32),
        jax.ShapeDtypeStruct((b,), i32),
        jax.ShapeDtypeStruct((b,), i32),
        cache, cache,
        jax.ShapeDtypeStruct((b, S), f32),
        jax.ShapeDtypeStruct((), i32),
        jax.ShapeDtypeStruct((d,), f32),
    ]
    n = len(pspecs)

    def fn(*args):
        return M.decode_step(cfg, list(args[:n]), *args[n:], use_pallas=True)

    text = to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text and "HloModule" in text
    assert len(text) > 10_000


def test_manifest_structure(artifacts_dir):
    with open(os.path.join(artifacts_dir, "manifest.json")) as f:
        man = json.load(f)
    assert set(man["models"]) == {"llama-analog", "olmoe-analog"}
    for name, m in man["models"].items():
        for key in ("params", "proj", "calib_dump"):
            assert os.path.exists(os.path.join(artifacts_dir, m[key])), (name, key)
        for tag, p in m["hlo"].items():
            assert os.path.exists(os.path.join(artifacts_dir, p)), (name, tag)
        assert m["param_order"] == sorted(m["param_order"])
    assert set(man["tasks"]) == {
        "knowledge", "arithmetic", "completion", "coreference", "negation",
        "hard_completion",
    }


def test_artifact_proj_is_orthogonal(artifacts_dir):
    for model in ("llama-analog", "olmoe-analog"):
        with np.load(os.path.join(artifacts_dir, model, "proj.npz")) as z:
            proj = z["proj"]
        L, nkv, d, _ = proj.shape
        for l in range(L):
            for g in range(nkv):
                np.testing.assert_allclose(
                    proj[l, g].T @ proj[l, g], np.eye(d), atol=1e-3)


def test_trained_model_knows_the_grammar(artifacts_dir):
    """End-to-end sanity on the real checkpoint: the model must complete a
    trained fact pattern (the basis of every table)."""
    from compile.config import MODELS
    from compile.train import load_params

    cfg = MODELS["llama-analog"]
    params = load_params(os.path.join(artifacts_dir, "llama-analog", "params.npz"))
    with np.load(os.path.join(artifacts_dir, "llama-analog", "proj.npz")) as z:
        proj = jnp.asarray(z["proj"])
    out = M.py_generate(cfg, params, proj, b"the capital of ", 28, k_ratio=1.0)
    text = out.decode("latin-1")
    assert " is " in text, f"model lost the fact pattern: {text!r}"


def test_calib_dump_has_figure_matrices(artifacts_dir):
    with np.load(os.path.join(artifacts_dir, "llama-analog", "calib_dump.npz")) as z:
        keys = set(z.files)
        gsz = int(z["group_size"])
        for j in range(gsz):
            assert f"eval_l0_q{j}" in keys
            assert f"devan_l0_q{j}" in keys
        assert {"eval_l0_k", "devan_l0_k", "proj_l0_g0", "proj_last_g0"} <= keys
