import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from compile.config import LLAMA_ANALOG, OLMOE_ANALOG  # noqa: E402


@pytest.fixture(scope="session")
def small_cfg():
    """A shrunken config so model tests stay fast."""
    from dataclasses import replace

    return replace(LLAMA_ANALOG, max_seq=64, train_seq=32, n_layers=2, d_ff=128)


@pytest.fixture(scope="session")
def small_mha_cfg(small_cfg):
    from dataclasses import replace

    return replace(small_cfg, name="mha", n_kv_heads=small_cfg.n_q_heads)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def artifacts_dir():
    """Real artifacts if `make artifacts` has run; else skip dependents."""
    path = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__)))), "artifacts")
    if not os.path.exists(os.path.join(path, "manifest.json")):
        pytest.skip("artifacts not built (run `make artifacts`)")
    return path
