"""L1 correctness: pallas kernels vs the pure-jnp oracle (hypothesis sweeps
shapes; the CORE correctness signal for the lowered hot path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aqua, ref

SCALE = 0.25


def make_inputs(rng, b, s, n_q, n_kv, d, valid):
    q = jnp.asarray(rng.normal(size=(b, n_q, d)), jnp.float32)
    kh = jnp.asarray(rng.normal(size=(b, s, n_kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, n_kv, d)), jnp.float32)
    p = np.linalg.qr(rng.normal(size=(n_kv, d, d)))[0].astype(np.float32)
    bias = jnp.where(jnp.arange(s)[None, :] < valid, 0.0, -1e9)
    bias = jnp.broadcast_to(bias, (b, s)).astype(jnp.float32)
    return q, kh, v, jnp.asarray(p), bias


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    s_blocks=st.integers(1, 4),
    group=st.integers(1, 4),
    n_kv=st.integers(1, 2),
    d=st.sampled_from([4, 8, 16]),
    k_frac=st.floats(0.2, 1.0),
    data=st.integers(0, 2**31 - 1),
)
def test_fused_matches_ref(b, s_blocks, group, n_kv, d, k_frac, data):
    rng = np.random.default_rng(data)
    s = 8 * s_blocks
    n_q = group * n_kv
    valid = rng.integers(1, s + 1)
    q, kh, v, p, bias = make_inputs(rng, b, s, n_q, n_kv, d, valid)
    k_dims = jnp.int32(max(1, round(k_frac * d)))
    keep = jnp.ones((d,), jnp.float32)
    c_ref, a_ref = ref.aqua_attention(q, kh, v, p, k_dims, keep, bias, SCALE)
    c_pl, a_pl = aqua.aqua_attention_fused(q, kh, v, p, k_dims, keep, bias, SCALE)
    np.testing.assert_allclose(c_ref, c_pl, atol=1e-5)
    np.testing.assert_allclose(a_ref, a_pl, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 2),
    nb=st.integers(2, 4),
    d=st.sampled_from([4, 8]),
    k_frac=st.floats(0.25, 1.0),
    data=st.integers(0, 2**31 - 1),
)
def test_tiled_matches_ref(b, nb, d, k_frac, data):
    rng = np.random.default_rng(data)
    block = 8
    s = block * nb
    n_kv, group = 1, 4
    valid = rng.integers(1, s + 1)
    q, kh, v, p, bias = make_inputs(rng, b, s, group * n_kv, n_kv, d, valid)
    k_dims = jnp.int32(max(1, round(k_frac * d)))
    keep = jnp.ones((d,), jnp.float32)
    c_ref, _ = ref.aqua_attention(q, kh, v, p, k_dims, keep, bias, SCALE)
    c_t = aqua.aqua_attention_tiled(q, kh, v, p, k_dims, keep, bias, SCALE, block_s=block)
    np.testing.assert_allclose(c_ref, c_t, atol=1e-4)


def test_memory_mask_applies():
    rng = np.random.default_rng(3)
    b, s, n_q, n_kv, d = 1, 8, 4, 1, 8
    q, kh, v, p, bias = make_inputs(rng, b, s, n_q, n_kv, d, s)
    keep = jnp.asarray([1, 1, 1, 1, 1, 1, 0, 0], jnp.float32)
    c1, _ = ref.aqua_attention(q, kh, v, p, jnp.int32(d), keep, bias, SCALE)
    c2, _ = aqua.aqua_attention_fused(q, kh, v, p, jnp.int32(d), keep, bias, SCALE)
    np.testing.assert_allclose(c1, c2, atol=1e-5)


def test_threshold_equals_static_topk():
    """Runtime-knob threshold mask == Algorithm 1's literal top-k gather."""
    rng = np.random.default_rng(4)
    for _ in range(20):
        d = int(rng.integers(2, 33))
        k = int(rng.integers(1, d + 1))
        qhat = jnp.asarray(rng.normal(size=(3, d)), jnp.float32)
        m_thr = ref.topk_mask(qhat, jnp.int32(k))
        m_sta = ref.topk_mask_static(qhat, k)
        np.testing.assert_array_equal(np.asarray(m_thr), np.asarray(m_sta))


def test_rotational_invariance_lemma():
    """Lemma A.4: with orthogonal P and k=d, AQUA scores == standard scores."""
    rng = np.random.default_rng(5)
    b, s, n_q, n_kv, d = 2, 16, 4, 2, 16
    q, k_raw, v, p, bias = make_inputs(rng, b, s, n_q, n_kv, d, s)
    # khat = k·P (projected cache)
    khat = jnp.einsum("bskd,kde->bske", k_raw, p)
    c_aqua, a_aqua = ref.aqua_attention(q, khat, v, p, jnp.int32(d),
                                        jnp.ones((d,), jnp.float32), bias, SCALE)
    c_std, a_std = ref.full_attention(q, k_raw, v, bias, SCALE)
    np.testing.assert_allclose(a_aqua, a_std, atol=1e-4)
    np.testing.assert_allclose(c_aqua, c_std, atol=1e-4)


def test_info_loss_zero_at_full_k():
    rng = np.random.default_rng(6)
    v = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
    p = jnp.asarray(np.linalg.qr(rng.normal(size=(8, 8)))[0], jnp.float32)
    vhat = v @ p
    loss = ref.info_retention_loss(v, vhat, jnp.ones((8,), jnp.float32))
    assert float(jnp.max(loss)) < 1e-4


def test_masked_scores_zero_out_dropped_dims():
    rng = np.random.default_rng(7)
    qhat = jnp.asarray(rng.normal(size=(1, 4, 8)), jnp.float32)
    mask = ref.topk_mask(qhat, jnp.int32(3))
    assert int(mask.sum()) == 3 * 4
    # masked entries are the smallest magnitudes
    mags = np.abs(np.asarray(qhat))
    for bi in range(1):
        for h in range(4):
            kept = mags[bi, h][np.asarray(mask)[bi, h] > 0.5]
            dropped = mags[bi, h][np.asarray(mask)[bi, h] < 0.5]
            assert kept.min() >= dropped.max() - 1e-6
