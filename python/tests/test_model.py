"""L2 model invariants: decode chain ≡ full forward, prefill ≡ decode,
AQUA knobs behave, GQA/MHA both wired correctly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def setup(small_cfg):
    params = M.init_params(small_cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 10), 0, small_cfg.vocab)
    return small_cfg, params, toks


def run_decode_chain(cfg, params, toks, proj, k_dims=None, use_pallas=True):
    b, t = toks.shape
    d = cfg.d_head
    k_dims = jnp.int32(d if k_dims is None else k_dims)
    plist = M.params_to_list(params)
    kc = jnp.zeros((cfg.n_layers, b, cfg.max_seq, cfg.n_kv_heads, d), jnp.float32)
    vc = jnp.zeros_like(kc)
    mask = jnp.zeros((b, cfg.max_seq), jnp.float32)
    keep = jnp.ones((d,), jnp.float32)
    logits = []
    for i in range(t):
        lg, kc, vc, acc = M.decode_step(cfg, plist, proj, toks[:, i],
                                        jnp.full((b,), i, jnp.int32), kc, vc,
                                        mask, k_dims, keep, use_pallas)
        mask = mask.at[:, i].set(1.0)
        logits.append(lg)
    return jnp.stack(logits, axis=1), kc, vc, acc


def test_param_names_sorted_and_complete(setup):
    cfg, params, _ = setup
    names = M.param_names(cfg)
    assert names == sorted(names)
    assert set(names) == set(params)


def test_decode_chain_matches_train_forward(setup):
    cfg, params, toks = setup
    full = M.train_forward(cfg, params, toks)
    chain, _, _, _ = run_decode_chain(cfg, params, toks, M.identity_proj(cfg))
    np.testing.assert_allclose(np.asarray(chain), np.asarray(full), atol=2e-4)


def test_projected_cache_is_lossless(setup):
    """Orthogonal P + k=d must reproduce the identity-P logits (Lemma A.4)."""
    cfg, params, toks = setup
    rng = np.random.default_rng(2)
    q = np.linalg.qr(rng.normal(size=(cfg.n_layers, cfg.n_kv_heads,
                                      cfg.d_head, cfg.d_head)))[0]
    proj = jnp.asarray(q, jnp.float32)
    base, _, _, _ = run_decode_chain(cfg, params, toks, M.identity_proj(cfg))
    rot, _, _, _ = run_decode_chain(cfg, params, toks, proj)
    np.testing.assert_allclose(np.asarray(rot), np.asarray(base), atol=3e-3)


def test_prefill_chunk_matches_decode_chain(small_cfg):
    from dataclasses import replace

    cfg = small_cfg
    params = M.init_params(cfg, jax.random.PRNGKey(3))
    b, c = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(4), (b, c), 0, cfg.vocab)
    proj = M.identity_proj(cfg)
    plist = M.params_to_list(params)
    kc = jnp.zeros((cfg.n_layers, b, cfg.max_seq, cfg.n_kv_heads, cfg.d_head), jnp.float32)
    vc = jnp.zeros_like(kc)
    mask = jnp.zeros((b, cfg.max_seq), jnp.float32)
    keep = jnp.ones((cfg.d_head,), jnp.float32)
    lg, kc2, vc2, mask2, acc = M.prefill_chunk(
        cfg, plist, proj, toks, jnp.zeros((b,), jnp.int32), kc, vc, mask,
        jnp.int32(cfg.d_head), keep)
    chain, kc1, vc1, _ = run_decode_chain(cfg, params, toks, proj)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(chain), atol=2e-4)
    np.testing.assert_allclose(np.asarray(kc1), np.asarray(kc2), atol=1e-5)
    # slot mask marks exactly the written region
    np.testing.assert_array_equal(np.asarray(mask2[:, :c]), np.ones((b, c), np.float32))
    assert float(mask2[:, c:].sum()) == 0.0


def test_aggressive_pruning_changes_logits(setup):
    cfg, params, toks = setup
    base, _, _, _ = run_decode_chain(cfg, params, toks, M.identity_proj(cfg))
    pruned, _, _, _ = run_decode_chain(cfg, params, toks, M.identity_proj(cfg),
                                       k_dims=max(1, cfg.d_head // 8))
    assert float(jnp.abs(base - pruned).max()) > 1e-3


def test_attn_acc_is_probability_mass(setup):
    cfg, params, toks = setup
    _, _, _, acc = run_decode_chain(cfg, params, toks, M.identity_proj(cfg))
    # at the last step each (layer, lane)'s mass sums to n_q_heads
    sums = np.asarray(acc).sum(axis=-1)
    np.testing.assert_allclose(sums, cfg.n_q_heads, rtol=1e-4)


def test_mha_variant_runs(small_mha_cfg):
    cfg = small_mha_cfg
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, 6), 0, cfg.vocab)
    full = M.train_forward(cfg, params, toks)
    chain, _, _, _ = run_decode_chain(cfg, params, toks, M.identity_proj(cfg))
    np.testing.assert_allclose(np.asarray(chain), np.asarray(full), atol=2e-4)


def test_rope_position_dependence(setup):
    cfg, params, _ = setup
    x = jnp.ones((1, cfg.n_q_heads, cfg.d_head), jnp.float32)
    r0 = M.apply_rope(x, jnp.array([0], jnp.int32), cfg.rope_theta)
    r5 = M.apply_rope(x, jnp.array([5], jnp.int32), cfg.rope_theta)
    assert float(jnp.abs(r0 - r5).max()) > 1e-3
    # norm preserved (rotation)
    np.testing.assert_allclose(jnp.linalg.norm(r0, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_py_generate_deterministic(small_cfg):
    cfg = small_cfg
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    proj = M.identity_proj(cfg)
    out1 = M.py_generate(cfg, params, proj, b"ab", 4)
    out2 = M.py_generate(cfg, params, proj, b"ab", 4)
    assert out1 == out2
    assert len(out1) == 4
