"""Corpus + task generation: determinism, disjoint scripts, task soundness."""

import json
import random

from compile import corpus as CORP
from compile import tasks as T


def test_world_deterministic():
    w1 = CORP.build_world(7)
    w2 = CORP.build_world(7)
    assert w1.capital == w2.capital
    assert w1.people == w2.people
    w3 = CORP.build_world(8)
    assert w1.capital != w3.capital


def test_anglish_is_ascii_and_devan_is_high_bytes():
    ang = CORP.corpus_bytes(CORP.generate_anglish(7, 50, salt=1))
    dev = CORP.corpus_bytes(CORP.generate_devan(7, 50))
    assert all(b < 128 for b in ang)
    payload = [b for b in dev if b not in (0x20, 0x0A, 0xFF)]
    assert payload and all(0xA1 <= b <= 0xDA for b in payload)
    # disjoint token distributions (the cross-lingual premise)
    assert not (set(ang) & set(payload))


def test_facts_consistent_between_corpus_and_tasks():
    seed = 7
    w = CORP.build_world(seed)
    rng = random.Random(0)
    items = T.gen_knowledge(w, rng, 20)
    for it in items:
        country = it["prompt"].split()[3]
        right = it["choices"][it["answer"]].strip()
        assert w.capital[country] == right


def test_arithmetic_targets_correct():
    w = CORP.build_world(1)
    rng = random.Random(0)
    for it in T.gen_arithmetic(w, rng, 30):
        toks = it["prompt"].split()
        a, b = int(toks[0]), int(toks[2])
        assert it["target"] == f" {a + b} ."


def test_mc_answers_in_range():
    w = CORP.build_world(2)
    rng = random.Random(3)
    for gen in [T.gen_knowledge, T.gen_completion, T.gen_coreference,
                T.gen_negation, T.gen_hard_completion]:
        for it in gen(w, rng, 10):
            assert 0 <= it["answer"] < len(it["choices"])
            assert len(set(it["choices"])) == len(it["choices"]), "duplicate choices"


def test_write_tasks_jsonl(tmp_path):
    man = T.write_tasks(5, str(tmp_path), n_items=4)
    assert set(man) == set(T.TASKS)
    for name, meta in man.items():
        lines = open(meta["path"], encoding="latin-1").read().strip().splitlines()
        assert len(lines) == 4
        for line in lines:
            json.loads(line)
        assert meta["analog_of"] == T.ANALOG_OF[name]


def test_sentence_distribution_covers_all_kinds():
    lines = CORP.generate_anglish(3, 2000, salt=9)
    text = "\n".join(lines)
    assert "the capital of" in text
    assert "plus" in text and "equals" in text
    assert "gave the" in text and "now has the" in text
    assert "is not" in text
