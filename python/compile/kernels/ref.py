"""Pure-jnp oracles for the AQUA attention kernels.

Every pallas kernel in ``aqua.py`` is validated against these references by
``python/tests/test_kernels.py`` (hypothesis sweeps shapes/dtypes). The rust
native kernels (``rust/src/aqua/native.rs``) are cross-checked against the
same semantics through the HLO executables.

Notation follows the paper (§3, Algorithm 1):
  q        [B, n_q, d]        current-step query (post-RoPE)
  khat     [B, S, n_kv, d]    *projected* key cache  K̂ = K·P
  v        [B, S, n_kv, d]    value cache
  P        [n_kv, d, d]       per-kv-group orthogonal projection
  k_dims   scalar i32         number of retained dimensions (k in the paper)
  dim_keep [d]                AQUA-Memory static mask (1.0 keep / 0.0 slice)
  slot_bias[B, S]             additive mask: 0 for valid slots, -1e9 else
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def project_q(q: jnp.ndarray, proj: jnp.ndarray, n_kv: int) -> jnp.ndarray:
    """q̂ = q·P using each query head's group projection.

    q [B, n_q, d], proj [n_kv, d, d] -> [B, n_q, d]. Query head h belongs to
    kv group h // (n_q/n_kv).
    """
    b, n_q, d = q.shape
    group = n_q // n_kv
    qg = q.reshape(b, n_kv, group, d)
    qhat = jnp.einsum("bkgd,kde->bkge", qg, proj)
    return qhat.reshape(b, n_q, d)


def topk_mask(qhat: jnp.ndarray, k_dims) -> jnp.ndarray:
    """Per-vector mask keeping the k largest-|·| dimensions (paper Alg. 1
    lines 4-6), expressed as a threshold so ``k_dims`` can be a *runtime*
    scalar. Ties at the threshold keep all tied dims (measure-zero for
    continuous activations; equivalence with the gather formulation is
    property-tested)."""
    d = qhat.shape[-1]
    k_dims = jnp.asarray(k_dims, jnp.int32)
    mag = jnp.abs(qhat)
    srt = jnp.sort(mag, axis=-1)  # ascending
    idx = jnp.clip(d - k_dims, 0, d - 1)
    thresh = jax.lax.dynamic_slice_in_dim(srt, idx, 1, axis=-1)
    mask = (mag >= thresh).astype(qhat.dtype)
    # k_dims >= d must keep everything even with ties at the minimum.
    return jnp.where(k_dims >= d, jnp.ones_like(mask), mask)


def topk_mask_static(qhat: jnp.ndarray, k: int) -> jnp.ndarray:
    """Mask from jax.lax.top_k (static k) — Algorithm 1's literal gather
    selection, used to property-test the threshold formulation."""
    d = qhat.shape[-1]
    _, idx = jax.lax.top_k(jnp.abs(qhat), k)
    return jax.nn.one_hot(idx, d, dtype=qhat.dtype).sum(axis=-2)


def aqua_scores(qtilde: jnp.ndarray, khat: jnp.ndarray, scale: float) -> jnp.ndarray:
    """S̃ = q̃·K̂ᵀ over the masked dims. qtilde [B,n_q,d], khat [B,S,n_kv,d]
    -> [B, n_q, S] (GQA head mapping applied)."""
    b, n_q, d = qtilde.shape
    n_kv = khat.shape[2]
    group = n_q // n_kv
    qg = qtilde.reshape(b, n_kv, group, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, khat) * scale
    return s.reshape(b, n_q, -1)


def aqua_attention(
    q: jnp.ndarray,
    khat: jnp.ndarray,
    v: jnp.ndarray,
    proj: jnp.ndarray,
    k_dims,
    dim_keep: jnp.ndarray,
    slot_bias: jnp.ndarray,
    scale: float,
):
    """Full AQUA attention step (reference).

    Returns (context [B, n_q, d], attn [B, n_q, S]).
    """
    n_kv = khat.shape[2]
    qhat = project_q(q, proj, n_kv) * dim_keep
    mask = topk_mask(qhat, k_dims)
    scores = aqua_scores(qhat * mask, khat, scale)
    scores = scores + slot_bias[:, None, :]
    attn = jax.nn.softmax(scores, axis=-1)
    b, n_q, s = attn.shape
    group = n_q // n_kv
    ag = attn.reshape(b, n_kv, group, s)
    ctx = jnp.einsum("bkgs,bskd->bkgd", ag, v).reshape(b, n_q, -1)
    return ctx, attn


def full_attention(q, k, v, slot_bias, scale):
    """Standard attention (paper §3) — the P=I, k=d special case, used as an
    independent oracle for the baseline-equivalence property."""
    b, n_q, d = q.shape
    n_kv = k.shape[2]
    ident = jnp.tile(jnp.eye(d, dtype=q.dtype)[None], (n_kv, 1, 1))
    return aqua_attention(
        q, k, v, ident, jnp.array(d, jnp.int32), jnp.ones((d,), q.dtype), slot_bias, scale
    )


def info_retention_loss(v: jnp.ndarray, vhat: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Paper §6.2: L_info = | ||v|| - ||v̂[I_k]|| | / ||v||  (rowwise)."""
    nv = jnp.linalg.norm(v, axis=-1)
    nr = jnp.linalg.norm(vhat * mask, axis=-1)
    return jnp.abs(nv - nr) / jnp.maximum(nv, 1e-12)
