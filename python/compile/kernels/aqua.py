"""Layer-1 Pallas kernels for AQUA attention.

Two kernels, both lowered with ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls; see DESIGN.md §Hardware-Adaptation for the real
TPU mapping):

* :func:`aqua_attention_fused` — the decode-path hot-spot. One grid step per
  batch lane; the whole K̂/V cache row for that lane is the kernel's working
  set (at this scale S·n_kv·d·4B·2 ≈ 0.5 MiB, comfortably VMEM-resident on a
  real TPU, so no sequence tiling is required). Computes: project q → apply
  AQUA-Memory dim mask → runtime top-k magnitude mask → masked scores →
  softmax → context, and returns the attention weights for the H2O
  accumulator.

* :func:`aqua_attention_tiled` — the long-context variant: FlashAttention
  style online-softmax accumulation over ``block_s``-sized K̂/V tiles,
  expressing the HBM↔VMEM schedule via BlockSpec index maps. Returns the
  context only (H2O weights need the full row, which defeats tiling).

Numerics of both are property-tested against ``ref.aqua_attention``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Fused single-tile kernel (decode hot path)
# ---------------------------------------------------------------------------


def _fused_kernel(q_ref, khat_ref, v_ref, proj_ref, kd_ref, keep_ref, bias_ref,
                  ctx_ref, attn_ref, *, scale: float, n_kv: int):
    q = q_ref[0]          # [n_q, d]
    khat = khat_ref[0]    # [S, n_kv, d]
    v = v_ref[0]          # [S, n_kv, d]
    proj = proj_ref[...]  # [n_kv, d, d]
    keep = keep_ref[...]  # [d]
    bias = bias_ref[0]    # [S]
    k_dims = kd_ref[0]

    n_q, d = q.shape
    group = n_q // n_kv

    # Project each query head with its group's P, then AQUA-Memory mask.
    qg = q.reshape(n_kv, group, d)
    qhat = jnp.einsum("kgd,kde->kge", qg, proj).reshape(n_q, d) * keep

    # Runtime top-k magnitude selection (threshold formulation of Alg. 1).
    mag = jnp.abs(qhat)
    srt = jnp.sort(mag, axis=-1)
    idx = jnp.clip(d - k_dims, 0, d - 1)
    thr = jax.lax.dynamic_slice_in_dim(srt, idx, 1, axis=-1)
    mask = (mag >= thr).astype(qhat.dtype)
    mask = jnp.where(k_dims >= d, jnp.ones_like(mask), mask)
    qt = (qhat * mask).reshape(n_kv, group, d)

    # Masked scores over the projected key cache (lossless rotation, §6.3.1).
    s = jnp.einsum("kgd,skd->kgs", qt, khat) * scale
    s = s.reshape(n_q, -1) + bias[None, :]

    # Stable softmax + context.
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    attn = e / jnp.sum(e, axis=-1, keepdims=True)
    ag = attn.reshape(n_kv, group, -1)
    ctx = jnp.einsum("kgs,skd->kgd", ag, v).reshape(n_q, d)

    ctx_ref[0] = ctx
    attn_ref[0] = attn


def aqua_attention_fused(q, khat, v, proj, k_dims, dim_keep, slot_bias, scale):
    """Pallas AQUA attention. Shapes as in ``ref.aqua_attention``;
    ``k_dims`` is a runtime i32 scalar. Returns (ctx [B,n_q,d], attn [B,n_q,S])."""
    b, n_q, d = q.shape
    s = khat.shape[1]
    n_kv = khat.shape[2]
    kd = jnp.asarray(k_dims, jnp.int32).reshape(1)

    kern = functools.partial(_fused_kernel, scale=scale, n_kv=n_kv)
    return pl.pallas_call(
        kern,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, n_q, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, n_kv, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, s, n_kv, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((n_kv, d, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_q, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n_q, s), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_q, d), q.dtype),
            jax.ShapeDtypeStruct((b, n_q, s), q.dtype),
        ],
        interpret=True,
    )(q, khat, v, proj, kd, dim_keep, slot_bias)


# ---------------------------------------------------------------------------
# Flash-style tiled kernel (long-context variant)
# ---------------------------------------------------------------------------


def _tiled_kernel(q_ref, khat_ref, v_ref, proj_ref, kd_ref, keep_ref, bias_ref,
                  ctx_ref, m_ref, l_ref, acc_ref, *, scale: float, n_kv: int,
                  n_blocks: int):
    j = pl.program_id(1)

    q = q_ref[0]
    khat = khat_ref[0]   # [bs, n_kv, d] — current KV tile
    v = v_ref[0]
    proj = proj_ref[...]
    keep = keep_ref[...]
    bias = bias_ref[0]   # [bs]
    k_dims = kd_ref[0]

    n_q, d = q.shape
    group = n_q // n_kv

    # q̂ / mask recomputed per tile (d is tiny; keeps the kernel stateless).
    qg = q.reshape(n_kv, group, d)
    qhat = jnp.einsum("kgd,kde->kge", qg, proj).reshape(n_q, d) * keep
    mag = jnp.abs(qhat)
    srt = jnp.sort(mag, axis=-1)
    idx = jnp.clip(d - k_dims, 0, d - 1)
    thr = jax.lax.dynamic_slice_in_dim(srt, idx, 1, axis=-1)
    mask = (mag >= thr).astype(qhat.dtype)
    mask = jnp.where(k_dims >= d, jnp.ones_like(mask), mask)
    qt = (qhat * mask).reshape(n_kv, group, d)

    s = jnp.einsum("kgd,skd->kgs", qt, khat) * scale
    s = s.reshape(n_q, -1) + bias[None, :]  # [n_q, bs]

    first = j == 0
    m_old = jnp.where(first, jnp.full((n_q,), NEG_INF, s.dtype), m_ref[0])
    l_old = jnp.where(first, jnp.zeros((n_q,), s.dtype), l_ref[0])
    acc_old = jnp.where(first, jnp.zeros_like(acc_ref[0]), acc_ref[0])

    m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
    corr = jnp.exp(m_old - m_new)
    e = jnp.exp(s - m_new[:, None])
    l_new = l_old * corr + jnp.sum(e, axis=-1)
    eg = e.reshape(n_kv, group, -1)
    pv = jnp.einsum("kgs,skd->kgd", eg, v).reshape(n_q, d)
    acc_new = acc_old * corr[:, None] + pv

    m_ref[0] = m_new
    l_ref[0] = l_new
    acc_ref[0] = acc_new
    # Last write (j == n_blocks-1) is the final context.
    ctx_ref[0] = acc_new / jnp.maximum(l_new, 1e-30)[:, None]


def aqua_attention_tiled(q, khat, v, proj, k_dims, dim_keep, slot_bias, scale,
                         block_s: int = 128):
    """Online-softmax AQUA attention over KV tiles. Returns ctx [B,n_q,d]."""
    b, n_q, d = q.shape
    s = khat.shape[1]
    n_kv = khat.shape[2]
    assert s % block_s == 0, "sequence capacity must be a multiple of block_s"
    nb = s // block_s
    kd = jnp.asarray(k_dims, jnp.int32).reshape(1)

    kern = functools.partial(_tiled_kernel, scale=scale, n_kv=n_kv, n_blocks=nb)
    ctx, _m, _l, _acc = pl.pallas_call(
        kern,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1, n_q, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_s, n_kv, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, block_s, n_kv, d), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((n_kv, d, d), lambda i, j: (0, 0, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
            pl.BlockSpec((1, block_s), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_q, d), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n_q), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n_q), lambda i, j: (i, 0)),
            pl.BlockSpec((1, n_q, d), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_q, d), q.dtype),
            jax.ShapeDtypeStruct((b, n_q), q.dtype),
            jax.ShapeDtypeStruct((b, n_q), q.dtype),
            jax.ShapeDtypeStruct((b, n_q, d), q.dtype),
        ],
        interpret=True,
    )(q, khat, v, proj, kd, dim_keep, slot_bias)
    return ctx
