"""Shared build-time configuration for the AQUA reproduction.

Two model variants mirror the paper's evaluation pair (scaled to the
CPU-trainable regime; see DESIGN.md "Substitutions"):

* ``llama-analog`` — Grouped-Query Attention with the paper's group size
  (N_Q = 4 query heads per kv head, §6.3's Fig-2 group exactly).
* ``olmoe-analog`` — Multi-Head Attention (one kv head per query head),
  the paper's architecture-contrast model.

Everything here is consumed by the build path only (train / calibrate /
aot); the rust runtime reads the same values from ``artifacts/manifest.json``.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Transformer LM hyperparameters (byte-level)."""

    name: str
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_q_heads: int = 4
    n_kv_heads: int = 1  # GQA group size = n_q_heads // n_kv_heads
    d_head: int = 32
    d_ff: int = 512
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    max_seq: int = 512       # serving KV-cache capacity S
    train_seq: int = 192     # training context length

    @property
    def group_size(self) -> int:
        assert self.n_q_heads % self.n_kv_heads == 0
        return self.n_q_heads // self.n_kv_heads

    def to_json_dict(self):
        d = asdict(self)
        d["group_size"] = self.group_size
        return d


LLAMA_ANALOG = ModelConfig(name="llama-analog", n_q_heads=4, n_kv_heads=1)
OLMOE_ANALOG = ModelConfig(name="olmoe-analog", n_q_heads=4, n_kv_heads=4)

MODELS = {m.name: m for m in (LLAMA_ANALOG, OLMOE_ANALOG)}


@dataclass(frozen=True)
class TrainConfig:
    """Tiny-but-real training run; loss curve recorded in EXPERIMENTS.md."""

    steps: int = 400
    batch: int = 12
    lr: float = 3e-3
    lr_min_frac: float = 0.1   # cosine decay floor
    warmup: int = 40
    adam_b1: float = 0.9
    adam_b2: float = 0.95
    adam_eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    seed: int = 0
    eval_every: int = 50
    eval_batches: int = 4


@dataclass(frozen=True)
class CorpusConfig:
    """Synthetic template-grammar corpora (see corpus.py)."""

    seed: int = 1234
    train_lines: int = 24_000
    valid_lines: int = 1_200
    calib_lines: int = 2_400
    crossling_lines: int = 1_200


@dataclass(frozen=True)
class CalibConfig:
    """Offline projection calibration (paper §6.1)."""

    batches: int = 24
    batch: int = 8
    seq: int = 192
    max_vectors_per_group: int = 4096  # subsample cap for SVD
    dump_vectors: int = 1024           # per matrix, for Figures 2-5
    seed: int = 7


# AOT lowering grid: one executable per (model, fn, batch).
DECODE_BATCHES = (1, 4)
PREFILL_CHUNK = 32

ARTIFACTS_DIR = "artifacts"
