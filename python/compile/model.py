"""Layer-2 JAX model: byte-level transformer LM with AQUA attention.

Three entry points matter:

* :func:`train_forward` — full-sequence causal forward with *standard*
  attention (training never uses AQUA; the paper applies AQUA at inference
  to frozen pre-trained weights). Can also return post-RoPE q/k activations
  for offline calibration (paper §6.1 step 2).
* :func:`decode_step` — the single-token auto-regressive step that is AOT
  lowered to HLO and driven by the rust coordinator. All AQUA knobs
  (projection stack P, runtime top-k ``k_dims``, AQUA-Memory ``dim_keep``)
  are *inputs*, so one executable serves every table row — with ``P = I``
  and ``k_dims = d`` it computes exactly standard attention.
* :func:`prefill_chunk` — a ``lax.scan`` of decode steps over a fixed-size
  prompt chunk (amortizes dispatch 32×); same knob semantics.

KV-cache convention (shared with rust, documented in the manifest):
  k_cache [L, B, S, n_kv, d]  — stores *projected* keys K̂ = K·P (+ the
                                AQUA-Memory dim mask already applied).
                                Lossless for attention by Lemma A.4.
  v_cache [L, B, S, n_kv, d]
  slot_mask [B, S] ∈ {0,1}    — valid cache slots. decode_step itself marks
                                the slot it writes.
  attn_acc [L, B, S]          — this step's attention mass per slot, summed
                                over query heads (H2O accumulator food).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import aqua as kernels
from .kernels import ref as kref

NEG = -1e9


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_names(cfg: ModelConfig) -> list[str]:
    """Flat parameter names in the canonical (sorted) order used for HLO
    argument passing. The rust runtime replicates this order from the
    manifest."""
    names = ["embed", "final_norm"]
    for l in range(cfg.n_layers):
        p = f"layers.{l:02d}."
        names += [p + n for n in
                  ("attn_norm", "mlp_norm", "w1", "w2", "w3", "wk", "wo", "wq", "wv")]
    return sorted(names)


def init_params(cfg: ModelConfig, key) -> dict:
    """Scaled-normal init (GPT-2 style residual scaling on wo/w2)."""
    d, h, f = cfg.d_model, cfg.d_head, cfg.d_ff
    nq, nkv = cfg.n_q_heads, cfg.n_kv_heads
    std = d ** -0.5
    res_std = std / (2 * cfg.n_layers) ** 0.5
    params = {}
    key, k1 = jax.random.split(key)
    params["embed"] = jax.random.normal(k1, (cfg.vocab, d), jnp.float32) * 0.02
    params["final_norm"] = jnp.ones((d,), jnp.float32)
    for l in range(cfg.n_layers):
        p = f"layers.{l:02d}."
        key, *ks = jax.random.split(key, 8)
        params[p + "attn_norm"] = jnp.ones((d,), jnp.float32)
        params[p + "mlp_norm"] = jnp.ones((d,), jnp.float32)
        params[p + "wq"] = jax.random.normal(ks[0], (d, nq * h), jnp.float32) * std
        params[p + "wk"] = jax.random.normal(ks[1], (d, nkv * h), jnp.float32) * std
        params[p + "wv"] = jax.random.normal(ks[2], (d, nkv * h), jnp.float32) * std
        params[p + "wo"] = jax.random.normal(ks[3], (nq * h, d), jnp.float32) * res_std
        params[p + "w1"] = jax.random.normal(ks[4], (d, f), jnp.float32) * std
        params[p + "w3"] = jax.random.normal(ks[5], (d, f), jnp.float32) * std
        params[p + "w2"] = jax.random.normal(ks[6], (f, d), jnp.float32) * res_std
    return params


def params_to_list(params: dict) -> list:
    return [params[n] for n in sorted(params)]


def params_from_list(cfg: ModelConfig, flat: list) -> dict:
    return dict(zip(param_names(cfg), flat))


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def _rope_cos_sin(pos, d_head, theta):
    """pos [...]-> cos/sin [..., d_head/2]."""
    half = d_head // 2
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, pos, theta):
    """x [..., H, d], pos broadcastable to x.shape[:-2]. Rotates (even, odd)
    interleaved pairs."""
    d = x.shape[-1]
    cos, sin = _rope_cos_sin(pos, d, theta)   # [..., d/2]
    cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out


def _qkv(cfg: ModelConfig, params, prefix, x):
    """x [..., d_model] -> q [..., n_q, d], k/v [..., n_kv, d]."""
    h = cfg.d_head
    q = (x @ params[prefix + "wq"]).reshape(*x.shape[:-1], cfg.n_q_heads, h)
    k = (x @ params[prefix + "wk"]).reshape(*x.shape[:-1], cfg.n_kv_heads, h)
    v = (x @ params[prefix + "wv"]).reshape(*x.shape[:-1], cfg.n_kv_heads, h)
    return q, k, v


def _mlp(params, prefix, x):
    return (jax.nn.silu(x @ params[prefix + "w1"]) * (x @ params[prefix + "w3"])) @ params[prefix + "w2"]


# ---------------------------------------------------------------------------
# Training / calibration forward (standard attention, full sequence)
# ---------------------------------------------------------------------------


def train_forward(cfg: ModelConfig, params: dict, tokens, collect_qk: bool = False):
    """tokens [B, T] int32 -> logits [B, T, vocab].

    With ``collect_qk`` also returns post-RoPE per-layer activations
    (qs: [L][B,T,n_q,d], ks: [L][B,T,n_kv,d]) for offline calibration.
    """
    b, t = tokens.shape
    scale = cfg.d_head ** -0.5
    x = params["embed"][tokens]
    pos = jnp.arange(t, dtype=jnp.int32)
    causal = jnp.where(pos[None, :] <= pos[:, None], 0.0, NEG)[None, None]  # [1,1,T,T]
    group = cfg.group_size
    qs, ks = [], []
    for l in range(cfg.n_layers):
        p = f"layers.{l:02d}."
        hdd = rmsnorm(x, params[p + "attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, params, p, hdd)
        q = apply_rope(q, pos[None, :].repeat(b, 0), cfg.rope_theta)
        k = apply_rope(k, pos[None, :].repeat(b, 0), cfg.rope_theta)
        if collect_qk:
            qs.append(q)
            ks.append(k)
        qg = q.reshape(b, t, cfg.n_kv_heads, group, cfg.d_head)
        s = jnp.einsum("bikgd,bjkd->bkgij", qg, k) * scale    # [B,nkv,g,T,T]
        s = s + causal
        a = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bkgij,bjkd->bikgd", a, v).reshape(b, t, -1)
        x = x + ctx @ params[p + "wo"]
        hdd = rmsnorm(x, params[p + "mlp_norm"], cfg.norm_eps)
        x = x + _mlp(params, p, hdd)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T
    if collect_qk:
        return logits, (qs, ks)
    return logits


# ---------------------------------------------------------------------------
# Decode step (the AOT-lowered request-path function)
# ---------------------------------------------------------------------------


def _attend(cfg, q, khat_row, v_row, proj, k_dims, dim_keep, bias, use_pallas):
    scale = cfg.d_head ** -0.5
    if use_pallas:
        return kernels.aqua_attention_fused(q, khat_row, v_row, proj, k_dims,
                                            dim_keep, bias, scale)
    return kref.aqua_attention(q, khat_row, v_row, proj, k_dims, dim_keep,
                               bias, scale)


def _decode_core(cfg: ModelConfig, params, proj, tokens, pos, k_cache, v_cache,
                 slot_mask, k_dims, dim_keep, use_pallas):
    """Single-token step shared by decode_step and prefill_chunk's scan body.

    tokens [B] i32, pos [B] i32. Returns (logits, k_cache, v_cache,
    slot_mask', attn_acc [L,B,S])."""
    b = tokens.shape[0]
    s_cap = k_cache.shape[2]

    # Mark the slot being written this step as attendable.
    cur = jax.nn.one_hot(pos, s_cap, dtype=slot_mask.dtype)  # [B,S]
    slot_mask = jnp.maximum(slot_mask, cur)
    bias = jnp.where(slot_mask > 0.5, 0.0, NEG)  # additive attention mask

    x = params["embed"][tokens]
    accs = []
    for l in range(cfg.n_layers):
        p = f"layers.{l:02d}."
        hdd = rmsnorm(x, params[p + "attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, params, p, hdd)   # q [B,nq,d], k/v [B,nkv,d]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        # Project keys into the calibrated space and statically slice
        # (AQUA-Memory) *before* caching — this is the memory saving.
        khat = jnp.einsum("bkd,kde->bke", k, proj[l]) * dim_keep

        def write(cache_l, val):
            # cache_l [B,S,nkv,d], val [B,nkv,d] written at pos[b].
            return jax.vmap(
                lambda c, vv, pp: jax.lax.dynamic_update_slice(c, vv[None], (pp, 0, 0))
            )(cache_l, val, pos)

        k_cache = k_cache.at[l].set(write(k_cache[l], khat))
        v_cache = v_cache.at[l].set(write(v_cache[l], v))

        ctx, attn = _attend(cfg, q, k_cache[l], v_cache[l], proj[l], k_dims,
                            dim_keep, bias, use_pallas)
        accs.append(jnp.sum(attn, axis=1))   # [B,S] — H2O mass this step
        x = x + ctx.reshape(b, -1) @ params[p + "wo"]
        hdd = rmsnorm(x, params[p + "mlp_norm"], cfg.norm_eps)
        x = x + _mlp(params, p, hdd)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T
    return logits, k_cache, v_cache, slot_mask, jnp.stack(accs)


def decode_step(cfg: ModelConfig, param_list, proj, tokens, pos, k_cache,
                v_cache, slot_mask, k_dims, dim_keep, use_pallas: bool = True):
    """The AOT entry point. ``param_list`` is the flat sorted param list
    (matches :func:`param_names`). Returns (logits [B,V], k_cache, v_cache,
    attn_acc [L,B,S])."""
    params = params_from_list(cfg, param_list)
    logits, kc, vc, _mask, acc = _decode_core(
        cfg, params, proj, tokens, pos, k_cache, v_cache, slot_mask,
        k_dims, dim_keep, use_pallas)
    return logits, kc, vc, acc


def prefill_chunk(cfg: ModelConfig, param_list, proj, tokens, pos0, k_cache,
                  v_cache, slot_mask, k_dims, dim_keep, use_pallas: bool = True):
    """Process a [B, C] chunk of prompt tokens via lax.scan of decode steps.

    ``pos0`` [B] is each lane's starting write position; token c lands at
    pos0+c. Lanes with fewer than C remaining tokens should be padded and
    masked by the caller (rust) — every scanned position *is* written, so
    the caller passes per-lane valid lengths through ``slot_mask`` cleanup
    afterwards (the engine simply never marks padding slots as valid for
    subsequent steps; see coordinator/kvcache.rs).

    Returns (logits [B, C, V], k_cache, v_cache, slot_mask, attn_acc [L,B,S]
    summed over the chunk).
    """
    params = params_from_list(cfg, param_list)

    def body(carry, tok_c):
        kc, vc, mask, acc, step = carry
        pos = pos0 + step
        logits, kc, vc, mask, a = _decode_core(
            cfg, params, proj, tok_c, pos, kc, vc, mask, k_dims, dim_keep,
            use_pallas)
        return (kc, vc, mask, acc + a, step + 1), logits

    acc0 = jnp.zeros((cfg.n_layers,) + slot_mask.shape, jnp.float32)
    (kc, vc, mask, acc, _), logits = jax.lax.scan(
        body, (k_cache, v_cache, slot_mask, acc0, jnp.int32(0)),
        jnp.transpose(tokens, (1, 0)))
    return jnp.transpose(logits, (1, 0, 2)), kc, vc, mask, acc


# ---------------------------------------------------------------------------
# Convenience: python-side generation (tests + sanity, not the request path)
# ---------------------------------------------------------------------------


def py_generate(cfg: ModelConfig, params: dict, proj, prompt: bytes,
                n_new: int, k_ratio: float = 1.0, s_ratio: float = 0.0,
                use_pallas: bool = False) -> bytes:
    """Greedy generation entirely in python — the oracle the rust engine's
    integration tests compare against."""
    d = cfg.d_head
    k_dims = jnp.int32(max(1, round(k_ratio * d)))
    keep = (jnp.arange(d) < round((1.0 - s_ratio) * d)).astype(jnp.float32)
    s_cap = cfg.max_seq
    plist = params_to_list(params)
    kc = jnp.zeros((cfg.n_layers, 1, s_cap, cfg.n_kv_heads, d), jnp.float32)
    vc = jnp.zeros_like(kc)
    mask = jnp.zeros((1, s_cap), jnp.float32)
    toks = list(prompt)
    logits = None
    for i, t in enumerate(toks):
        logits, kc, vc, acc = decode_step(
            cfg, plist, proj, jnp.array([t], jnp.int32), jnp.array([i], jnp.int32),
            kc, vc, mask, k_dims, keep, use_pallas)
        mask = mask.at[0, i].set(1.0)
    out = []
    for j in range(n_new):
        nxt = int(jnp.argmax(logits[0]))
        out.append(nxt)
        i = len(toks) + j
        if i >= s_cap:
            break
        logits, kc, vc, acc = decode_step(
            cfg, plist, proj, jnp.array([nxt], jnp.int32), jnp.array([i], jnp.int32),
            kc, vc, mask, k_dims, keep, use_pallas)
        mask = mask.at[0, i].set(1.0)
    return bytes(out)


def identity_proj(cfg: ModelConfig):
    return jnp.tile(jnp.eye(cfg.d_head, dtype=jnp.float32)[None, None],
                    (cfg.n_layers, cfg.n_kv_heads, 1, 1))
