"""Build-time training of the analog models (hand-rolled Adam; optax is not
available in this image).

The trained checkpoints play the role of the paper's pre-trained Llama/OLMoE
weights: AQUA is applied *post-hoc* to them at inference time. The loss curve
of each run is recorded in EXPERIMENTS.md (end-to-end validation
requirement).
"""

from __future__ import annotations

import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .config import ModelConfig, TrainConfig


# ---------------------------------------------------------------------------
# Data pipeline: corpus bytes -> [B, T+1] windows
# ---------------------------------------------------------------------------


class ByteDataset:
    def __init__(self, data: bytes, seq: int, seed: int):
        self.arr = np.frombuffer(data, dtype=np.uint8).astype(np.int32)
        self.seq = seq
        self.rng = np.random.default_rng(seed)
        assert len(self.arr) > seq + 2, "corpus too small"

    def batch(self, b: int) -> np.ndarray:
        starts = self.rng.integers(0, len(self.arr) - self.seq - 1, size=b)
        return np.stack([self.arr[s:s + self.seq + 1] for s in starts])


# ---------------------------------------------------------------------------
# Loss / optimizer
# ---------------------------------------------------------------------------


def lm_loss(cfg: ModelConfig, params: dict, batch: jnp.ndarray) -> jnp.ndarray:
    toks, targets = batch[:, :-1], batch[:, 1:]
    logits = M.train_forward(cfg, params, toks)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def adam_init(params: dict):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.int32(0)}


def adam_update(tc: TrainConfig, params, grads, state, lr):
    t = state["t"] + 1
    b1, b2, eps = tc.adam_b1, tc.adam_b2, tc.adam_eps
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    clip = jnp.minimum(1.0, tc.grad_clip / jnp.maximum(gnorm, 1e-9))
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k] * clip
        m = b1 * state["m"][k] + (1 - b1) * g
        v = b2 * state["v"][k] + (1 - b2) * g * g
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        upd = mh / (jnp.sqrt(vh) + eps)
        decay = 0.0 if params[k].ndim == 1 else tc.weight_decay
        new_p[k] = params[k] - lr * (upd + decay * params[k])
        new_m[k], new_v[k] = m, v
    return new_p, {"m": new_m, "v": new_v, "t": t}


def lr_at(tc: TrainConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / tc.warmup)
    prog = jnp.clip((step - tc.warmup) / max(1, tc.steps - tc.warmup), 0.0, 1.0)
    cos = tc.lr_min_frac + (1 - tc.lr_min_frac) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return tc.lr * warm * cos


# ---------------------------------------------------------------------------
# Training loop
# ---------------------------------------------------------------------------


def train(cfg: ModelConfig, tc: TrainConfig, train_bytes: bytes,
          valid_bytes: bytes, log=print) -> tuple[dict, list]:
    ds = ByteDataset(train_bytes, cfg.train_seq, tc.seed + 11)
    vs = ByteDataset(valid_bytes, cfg.train_seq, tc.seed + 13)
    params = M.init_params(cfg, jax.random.PRNGKey(tc.seed))
    state = adam_init(params)

    @jax.jit
    def step_fn(params, state, batch, step):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, batch))(params)
        params, state = adam_update(tc, params, grads, state, lr_at(tc, step))
        return params, state, loss

    @jax.jit
    def eval_fn(params, batch):
        return lm_loss(cfg, params, batch)

    curve = []
    t0 = time.time()
    for step in range(tc.steps):
        batch = jnp.asarray(ds.batch(tc.batch))
        params, state, loss = step_fn(params, state, batch, step)
        if step % tc.eval_every == 0 or step == tc.steps - 1:
            vloss = float(np.mean([eval_fn(params, jnp.asarray(vs.batch(tc.batch)))
                                   for _ in range(tc.eval_batches)]))
            curve.append({"step": step, "train_loss": float(loss), "valid_loss": vloss})
            log(f"[train:{cfg.name}] step {step:4d} loss {float(loss):.4f} "
                f"valid {vloss:.4f} ({time.time()-t0:.0f}s)")
    return params, curve


def save_params(params: dict, path: str):
    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path: str) -> dict:
    with np.load(path) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}
