"""Offline projection-matrix calibration (paper §6.1) + activation dumps for
the figure analyses.

For each (layer, kv-group) we stack the group's query matrices and the shared
key matrix vertically (paper §6.3):

    D_calib = [ D_q1 ; D_q2 ; ... ; D_qN ; D_k ]  ∈ R^{(N+1)M × d}

and take the right singular vectors V of its SVD as the projection P. P is
orthogonal, so caching K̂ = K·P is a lossless rotation (Lemma A.4).

Also dumps raw post-RoPE q/k samples (calibration split + held-out eval split
+ the cross-lingual ``devan`` split) that the rust analysis binaries use to
regenerate Figures 2, 3/4 and 5.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import model as M
from .config import CalibConfig, ModelConfig
from .train import ByteDataset


def collect_activations(cfg: ModelConfig, params: dict, data: bytes,
                        cc: CalibConfig, seed_salt: int = 0):
    """Run the frozen model over a corpus; return per-layer post-RoPE
    activations: qs [L][N, n_q, d], ks [L][N, n_kv, d] (N = batches·batch·seq,
    subsampled to max_vectors_per_group rows)."""
    ds = ByteDataset(data, cc.seq, cc.seed + seed_salt)
    fwd = jax.jit(lambda p, t: M.train_forward(cfg, p, t, collect_qk=True)[1])
    qs = [[] for _ in range(cfg.n_layers)]
    ks = [[] for _ in range(cfg.n_layers)]
    for _ in range(cc.batches):
        toks = jnp.asarray(ds.batch(cc.batch)[:, :-1])
        lq, lk = fwd(params, toks)
        for l in range(cfg.n_layers):
            qs[l].append(np.asarray(lq[l]).reshape(-1, cfg.n_q_heads, cfg.d_head))
            ks[l].append(np.asarray(lk[l]).reshape(-1, cfg.n_kv_heads, cfg.d_head))
    rng = np.random.default_rng(cc.seed + 100 + seed_salt)
    out_q, out_k = [], []
    for l in range(cfg.n_layers):
        q = np.concatenate(qs[l])
        k = np.concatenate(ks[l])
        idx = rng.permutation(len(q))[: cc.max_vectors_per_group]
        out_q.append(q[idx])
        out_k.append(k[idx])
    return out_q, out_k


def gqa_stack(cfg: ModelConfig, q_l: np.ndarray, k_l: np.ndarray, group: int) -> np.ndarray:
    """Build D_calib for kv-group ``group``: stack its query heads + the
    shared key head."""
    gsz = cfg.group_size
    q_heads = [q_l[:, group * gsz + j, :] for j in range(gsz)]
    return np.concatenate(q_heads + [k_l[:, group, :]], axis=0)


def svd_projection(d_calib: np.ndarray) -> np.ndarray:
    """P = V from D = UΣVᵀ (right singular vectors, columns ordered by
    decreasing variance)."""
    _, _, vt = np.linalg.svd(d_calib, full_matrices=True)
    return vt.T.astype(np.float32)  # [d, d]


def calibrate(cfg: ModelConfig, params: dict, calib_bytes: bytes,
              cc: CalibConfig):
    """Returns proj [L, n_kv, d, d] plus the raw activations used."""
    qs, ks = collect_activations(cfg, params, calib_bytes, cc)
    proj = np.zeros((cfg.n_layers, cfg.n_kv_heads, cfg.d_head, cfg.d_head),
                    np.float32)
    for l in range(cfg.n_layers):
        for g in range(cfg.n_kv_heads):
            p = svd_projection(gqa_stack(cfg, qs[l], ks[l], g))
            err = np.abs(p.T @ p - np.eye(cfg.d_head)).max()
            assert err < 1e-3, f"P not orthogonal (layer {l} group {g}): {err}"
            proj[l, g] = p
    return proj, (qs, ks)


def dump_for_figures(cfg: ModelConfig, params: dict, proj: np.ndarray,
                     eval_bytes: bytes, devan_bytes: bytes, cc: CalibConfig,
                     path: str):
    """Write the npz consumed by `aqua fig2|fig3|fig5`:

    - eval-split q/k for layer 0 group 0 (Fig 2 online-vs-offline) and the
      *last* layer (Fig 5 overlap),
    - devan-split q/k for the same group (Fig 3/4 cross-lingual),
    - the calibrated P for those groups.
    Vectors capped at cc.dump_vectors rows.
    """
    n = cc.dump_vectors
    qs_e, ks_e = collect_activations(cfg, params, eval_bytes, cc, seed_salt=31)
    qs_d, ks_d = collect_activations(cfg, params, devan_bytes, cc, seed_salt=57)
    last = cfg.n_layers - 1
    gsz = cfg.group_size
    out = {
        "proj_l0_g0": proj[0, 0],
        "proj_last_g0": proj[last, 0],
        "group_size": np.int32(gsz),
    }
    for tag, (qs, ks) in (("eval", (qs_e, ks_e)), ("devan", (qs_d, ks_d))):
        for j in range(gsz):
            out[f"{tag}_l0_q{j}"] = qs[0][:n, j, :]
        out[f"{tag}_l0_k"] = ks[0][:n, 0, :]
    for j in range(gsz):
        out[f"eval_last_q{j}"] = qs_e[last][:n, j, :]
    out["eval_last_k"] = ks_e[last][:n, 0, :]
    np.savez(path, **out)
    return sorted(out)
