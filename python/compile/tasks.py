"""SynthBench: the synthetic analogs of the paper's benchmark suite.

Task mechanics mirror the lm-evaluation-harness exactly; only the content is
synthetic (DESIGN.md "Substitutions"):

| analog of   | task id      | mechanism                                      |
|-------------|--------------|------------------------------------------------|
| MMLU        | knowledge    | MC by logprob: "the capital of X is" + choices |
| GSM8K       | arithmetic   | greedy generation, exact match                 |
| HellaSwag   | completion   | MC: grammatical vs corrupted sentence ending   |
| WinoGrande  | coreference  | MC: who holds the object after a transfer      |
| TruthfulQA  | negation     | MC: consistent vs contradictory continuation   |
| ARC         | hard_completion | MC with 4 distractors (harder margin)       |

Each task is a JSONL file; the rust eval harness (`rust/src/eval/`) scores MC
items by summed continuation logprob and gen items by greedy exact-match.
"""

from __future__ import annotations

import json
import os
import random

from .corpus import World, build_world


def _mc(prompt: str, choices: list[str], answer: int) -> dict:
    return {"type": "mc", "prompt": prompt, "choices": choices, "answer": answer}


def _gen(prompt: str, target: str) -> dict:
    return {"type": "gen", "prompt": prompt, "target": target}


def gen_knowledge(w: World, rng: random.Random, n: int) -> list[dict]:
    items = []
    for _ in range(n):
        c = rng.choice(w.countries)
        right = w.capital[c]
        wrong = rng.sample([x for x in w.cities if x != right], 3)
        choices = wrong + [right]
        rng.shuffle(choices)
        items.append(_mc(f"the capital of {c} is", [" " + x for x in choices],
                         choices.index(right)))
    return items


def gen_arithmetic(w: World, rng: random.Random, n: int) -> list[dict]:
    items = []
    for _ in range(n):
        a, b = rng.randrange(0, 10), rng.randrange(0, 10)
        items.append(_gen(f"{a} plus {b} equals", f" {a + b} ."))
    return items


def gen_completion(w: World, rng: random.Random, n: int) -> list[dict]:
    items = []
    for _ in range(n):
        adj, noun, verb, obj = (rng.choice(w.adjectives), rng.choice(w.nouns),
                                rng.choice(w.verbs), rng.choice(w.nouns))
        good = f" {verb} the {obj} ."
        # corrupted ending: word-order violation the grammar never produces
        bad = f" the {verb} {obj} ."
        choices = [good, bad]
        rng.shuffle(choices)
        items.append(_mc(f"the {adj} {noun}", choices, choices.index(good)))
    return items


def gen_coreference(w: World, rng: random.Random, n: int) -> list[dict]:
    items = []
    for _ in range(n):
        a, b = rng.sample(w.people, 2)
        noun = rng.choice(w.nouns)
        prompt = f"{a} gave the {noun} to {b} ."
        choices = [f" {b} now has the {noun} .", f" {a} now has the {noun} ."]
        items.append(_mc(prompt, choices, 0))
    return items


def gen_negation(w: World, rng: random.Random, n: int) -> list[dict]:
    items = []
    for _ in range(n):
        adj, opp = rng.choice(w.antonyms)
        p = rng.choice(w.people)
        prompt = f"{p} is {adj} ."
        choices = [f" {p} is not {opp} .", f" {p} is not {adj} ."]
        items.append(_mc(prompt, choices, 0))
    return items


def gen_hard_completion(w: World, rng: random.Random, n: int) -> list[dict]:
    """4-way completion with subtler distractors (ARC-Challenge analog)."""
    items = []
    for _ in range(n):
        adj, noun, verb, obj = (rng.choice(w.adjectives), rng.choice(w.nouns),
                                rng.choice(w.verbs), rng.choice(w.nouns))
        good = f" {verb} the {obj} ."
        # distractors are never prefixes of the answer (length-bias guard;
        # scoring is additionally length-normalized, lm-eval acc_norm style)
        distract = [
            f" {verb} the {verb} .",         # verb in noun slot
            f" {verb} {obj} .",              # missing article
            f" the {obj} {verb} .",          # inverted
        ]
        choices = [good] + distract
        rng.shuffle(choices)
        items.append(_mc(f"the {adj} {noun}", choices, choices.index(good)))
    return items


TASKS = {
    "knowledge": gen_knowledge,
    "arithmetic": gen_arithmetic,
    "completion": gen_completion,
    "coreference": gen_coreference,
    "negation": gen_negation,
    "hard_completion": gen_hard_completion,
}

# paper benchmark each task stands in for (manifest metadata for tables)
ANALOG_OF = {
    "knowledge": "MMLU",
    "arithmetic": "GSM8K",
    "completion": "HellaSwag",
    "coreference": "WinoGrande",
    "negation": "TruthfulQA-MC2",
    "hard_completion": "ARC-Challenge",
}


def write_tasks(seed: int, out_dir: str, n_items: int = 60) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    w = build_world(seed)
    manifest = {}
    for name, fn in TASKS.items():
        rng = random.Random(seed * 31337 + hash(name) % 100000)
        items = fn(w, rng, n_items)
        path = os.path.join(out_dir, f"{name}.jsonl")
        with open(path, "w", encoding="latin-1") as f:
            for it in items:
                f.write(json.dumps(it) + "\n")
        manifest[name] = {"path": path, "items": len(items),
                          "analog_of": ANALOG_OF[name]}
    return manifest
