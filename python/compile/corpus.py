"""Synthetic template-grammar corpora.

Stand-ins for the paper's data (DESIGN.md "Substitutions"):

* ``anglish`` — an ASCII pseudo-language. Train/calibration split plays the
  role of BookCorpus; a held-out split plays WikiText (perplexity + figure
  analyses); task files generated from the same grammar play the role of the
  MMLU/GSM8K/HellaSwag/WinoGrande/TruthfulQA/ARC suite.
* ``devan`` — a second pseudo-language over a *disjoint* high byte range
  (0xA1..0xDA, one byte per "letter", mimicking a different script) with a
  different syllable and sentence structure. Used only for the cross-lingual
  projection-transfer analysis (paper Fig. 3/4).

Everything is deterministic given the seed so python tests, the rust engine,
and the benches all see the same world.
"""

from __future__ import annotations

import random
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# Grammar worlds
# ---------------------------------------------------------------------------

_ANG_ONSETS = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z"]
_ANG_VOWELS = ["a", "e", "i", "o", "u"]
_ANG_CODAS = ["", "n", "r", "s", "l", "m"]

_DEV_CHARS = [bytes([c]).decode("latin-1") for c in range(0xA1, 0xDB)]


def _ang_word(rng: random.Random, syllables: int) -> str:
    parts = []
    for _ in range(syllables):
        parts.append(rng.choice(_ANG_ONSETS) + rng.choice(_ANG_VOWELS) + rng.choice(_ANG_CODAS))
    return "".join(parts)


def _dev_word(rng: random.Random, length: int) -> str:
    return "".join(rng.choice(_DEV_CHARS) for _ in range(length))


@dataclass
class World:
    """The closed world of entities/facts that both the corpus and the
    evaluation tasks are generated from. Facts are fixed per seed, so the
    knowledge tasks query exactly what the training corpus taught."""

    people: list = field(default_factory=list)
    countries: list = field(default_factory=list)
    cities: list = field(default_factory=list)
    nouns: list = field(default_factory=list)
    adjectives: list = field(default_factory=list)
    antonyms: list = field(default_factory=list)  # (adj, opposite) pairs
    colors: list = field(default_factory=list)
    verbs: list = field(default_factory=list)
    capital: dict = field(default_factory=dict)   # country -> city
    color_of: dict = field(default_factory=dict)  # noun -> color


def build_world(seed: int) -> World:
    rng = random.Random(seed * 7919 + 13)
    w = World()
    used = set()

    def fresh(gen):
        for _ in range(1000):
            word = gen()
            if word not in used:
                used.add(word)
                return word
        raise RuntimeError("word space exhausted")

    w.people = [fresh(lambda: _ang_word(rng, 2)) for _ in range(12)]
    w.countries = [fresh(lambda: _ang_word(rng, 3)) for _ in range(12)]
    w.cities = [fresh(lambda: _ang_word(rng, 2)) for _ in range(12)]
    w.nouns = [fresh(lambda: _ang_word(rng, 2)) for _ in range(12)]
    w.adjectives = [fresh(lambda: _ang_word(rng, 2)) for _ in range(10)]
    w.colors = [fresh(lambda: _ang_word(rng, 1)) for _ in range(8)]
    w.verbs = [fresh(lambda: _ang_word(rng, 2)) for _ in range(8)]
    w.antonyms = [(w.adjectives[i], w.adjectives[i + 1]) for i in range(0, 10, 2)]
    cities = w.cities[:]
    rng.shuffle(cities)
    w.capital = dict(zip(w.countries, cities))
    w.color_of = {n: rng.choice(w.colors) for n in w.nouns}
    return w


# ---------------------------------------------------------------------------
# Sentence templates (anglish)
# ---------------------------------------------------------------------------


def sent_fact_capital(w: World, rng: random.Random) -> str:
    c = rng.choice(w.countries)
    return f"the capital of {c} is {w.capital[c]} ."


def sent_fact_color(w: World, rng: random.Random) -> str:
    n = rng.choice(w.nouns)
    return f"the color of {n} is {w.color_of[n]} ."


def sent_arith(w: World, rng: random.Random) -> str:
    # single-digit operands: 100 facts, memorizable at the ~1M-param scale
    # (the GSM8K analog must sit *above* floor at baseline so Table 1 can
    # show the paper's reasoning-collapses-first shape)
    a, b = rng.randrange(0, 10), rng.randrange(0, 10)
    return f"{a} plus {b} equals {a + b} ."


def sent_narrative(w: World, rng: random.Random) -> str:
    return (
        f"the {rng.choice(w.adjectives)} {rng.choice(w.nouns)} "
        f"{rng.choice(w.verbs)} the {rng.choice(w.nouns)} ."
    )


def sent_coref(w: World, rng: random.Random) -> str:
    a, b = rng.sample(w.people, 2)
    n = rng.choice(w.nouns)
    return f"{a} gave the {n} to {b} . {b} now has the {n} ."


def sent_negation(w: World, rng: random.Random) -> str:
    adj, opp = rng.choice(w.antonyms)
    p = rng.choice(w.people)
    return f"{p} is {adj} . {p} is not {opp} ."


_SENTENCES = [
    (sent_narrative, 0.30),
    (sent_fact_capital, 0.14),
    (sent_fact_color, 0.12),
    (sent_arith, 0.20),
    (sent_coref, 0.12),
    (sent_negation, 0.12),
]


def anglish_line(w: World, rng: random.Random) -> str:
    r = rng.random()
    acc = 0.0
    for fn, p in _SENTENCES:
        acc += p
        if r <= acc:
            return fn(w, rng)
    return sent_narrative(w, rng)


# ---------------------------------------------------------------------------
# devan (cross-lingual set)
# ---------------------------------------------------------------------------


def devan_line(rng: random.Random) -> str:
    """Different script AND different structure: longer words, no 'the',
    verb-final order, danda-like terminator."""
    n = rng.randrange(3, 7)
    words = [_dev_word(rng, rng.randrange(2, 6)) for _ in range(n)]
    return " ".join(words) + " ÿ"  # 0xFF as sentence mark (latin-1)


# ---------------------------------------------------------------------------
# Corpus assembly
# ---------------------------------------------------------------------------


def generate_anglish(seed: int, n_lines: int, salt: int) -> list[str]:
    w = build_world(seed)
    rng = random.Random(seed * 104729 + salt)
    return [anglish_line(w, rng) for _ in range(n_lines)]


def generate_devan(seed: int, n_lines: int) -> list[str]:
    rng = random.Random(seed * 15485863 + 5)
    return [devan_line(rng) for _ in range(n_lines)]


def corpus_bytes(lines: list[str]) -> bytes:
    return ("\n".join(lines) + "\n").encode("latin-1")


def write_corpora(cfg, out_dir: str) -> dict:
    """Emit all corpus splits; returns manifest fragment."""
    import os

    os.makedirs(out_dir, exist_ok=True)
    splits = {
        "train": generate_anglish(cfg.seed, cfg.train_lines, salt=1),
        "valid": generate_anglish(cfg.seed, cfg.valid_lines, salt=2),
        "calib": generate_anglish(cfg.seed, cfg.calib_lines, salt=3),
        "devan": generate_devan(cfg.seed, cfg.crossling_lines),
    }
    out = {}
    for name, lines in splits.items():
        path = os.path.join(out_dir, f"{name}.txt")
        with open(path, "wb") as f:
            f.write(corpus_bytes(lines))
        out[name] = {"path": path, "lines": len(lines)}
    return out
