"""AOT build orchestrator — the only python entry point (`make artifacts`).

Idempotent pipeline (each stage skipped if its outputs already exist):

  corpus    -> artifacts/corpus/{train,valid,calib,devan}.txt
  train     -> artifacts/<model>/params.npz (+ loss curve in manifest)
  calibrate -> artifacts/<model>/proj.npz, artifacts/<model>/calib_dump.npz
  tasks     -> artifacts/tasks/*.jsonl
  lower     -> artifacts/<model>/{decode_bN,prefill_bN_cC}.hlo.txt
  manifest  -> artifacts/manifest.json

HLO **text** is the interchange format (NOT serialized HloModuleProto): the
image's xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction ids; the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _log(msg: str):
    print(f"[aot] {msg}", flush=True)


def build(artifacts: str, force: bool = False, fast: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from . import calibrate as C
    from . import corpus as CORP
    from . import model as M
    from . import tasks as T
    from . import train as TR
    from .config import (CalibConfig, CorpusConfig, DECODE_BATCHES, MODELS,
                         PREFILL_CHUNK, TrainConfig)

    os.makedirs(artifacts, exist_ok=True)
    manifest_path = os.path.join(artifacts, "manifest.json")
    manifest = {"models": {}, "corpus": {}, "tasks": {}, "train": {}}

    # ------------------------------------------------------------------ corpus
    ccfg = CorpusConfig()
    corpus_dir = os.path.join(artifacts, "corpus")
    marker = os.path.join(corpus_dir, "devan.txt")
    if force or not os.path.exists(marker):
        _log("generating corpora")
        manifest["corpus"] = CORP.write_corpora(ccfg, corpus_dir)
    else:
        manifest["corpus"] = {n: {"path": os.path.join(corpus_dir, f"{n}.txt")}
                              for n in ("train", "valid", "calib", "devan")}

    def read(split):
        with open(manifest["corpus"][split]["path"], "rb") as f:
            return f.read()

    # ------------------------------------------------------------------ tasks
    tasks_dir = os.path.join(artifacts, "tasks")
    if force or not os.path.exists(os.path.join(tasks_dir, "knowledge.jsonl")):
        _log("generating SynthBench task files")
        manifest["tasks"] = T.write_tasks(ccfg.seed, tasks_dir,
                                          n_items=20 if fast else 60)
    else:
        manifest["tasks"] = {n: {"path": os.path.join(tasks_dir, f"{n}.jsonl"),
                                 "analog_of": T.ANALOG_OF[n]} for n in T.TASKS}

    # ------------------------------------------------- per-model: train/calib
    tcfg = TrainConfig(steps=60 if fast else 400)
    calcfg = CalibConfig(batches=4 if fast else 24)
    for name, cfg in MODELS.items():
        mdir = os.path.join(artifacts, name)
        os.makedirs(mdir, exist_ok=True)
        params_path = os.path.join(mdir, "params.npz")
        proj_path = os.path.join(mdir, "proj.npz")
        dump_path = os.path.join(mdir, "calib_dump.npz")

        if force or not os.path.exists(params_path):
            _log(f"training {name} ({tcfg.steps} steps)")
            t0 = time.time()
            params, curve = TR.train(cfg, tcfg, read("train"), read("valid"), log=_log)
            TR.save_params(params, params_path)
            manifest["train"][name] = {"curve": curve,
                                       "wall_s": round(time.time() - t0, 1)}
        else:
            params = TR.load_params(params_path)
            # keep the original run's curve if preserved
            log_path = os.path.join(artifacts, "train_log.json")
            prev = {}
            if os.path.exists(log_path):
                with open(log_path) as f:
                    prev = json.load(f).get(name, {})
            manifest["train"][name] = prev or {"curve": [], "wall_s": 0.0,
                                               "note": "reused existing checkpoint"}

        if force or not os.path.exists(proj_path):
            _log(f"calibrating projections for {name}")
            proj, _ = C.calibrate(cfg, params, read("calib"), calcfg)
            np.savez(proj_path, proj=proj)
            _log(f"dumping figure activations for {name}")
            C.dump_for_figures(cfg, params, proj, read("valid"), read("devan"),
                               calcfg, dump_path)
        else:
            with np.load(proj_path) as z:
                proj = z["proj"]

        # ------------------------------------------------------------- lower
        import jax.numpy as jnp

        d, L, nkv, nq = cfg.d_head, cfg.n_layers, cfg.n_kv_heads, cfg.n_q_heads
        S, V = cfg.max_seq, cfg.vocab
        f32, i32 = jnp.float32, jnp.int32
        plist = [params[k] for k in sorted(params)]
        pspecs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in plist]

        hlo_entries = {}

        def lower_fn(tag, fn, specs):
            path = os.path.join(mdir, f"{tag}.hlo.txt")
            if not force and os.path.exists(path):
                hlo_entries[tag] = path
                return
            _log(f"lowering {name}/{tag}")
            text = to_hlo_text(jax.jit(fn).lower(*specs))
            with open(path, "w") as f:
                f.write(text)
            hlo_entries[tag] = path

        for b in DECODE_BATCHES:
            common = [
                jax.ShapeDtypeStruct((L, nkv, d, d), f32),      # proj
            ]
            cache = jax.ShapeDtypeStruct((L, b, S, nkv, d), f32)
            decode_specs = pspecs + common + [
                jax.ShapeDtypeStruct((b,), i32),                # tokens
                jax.ShapeDtypeStruct((b,), i32),                # pos
                cache, cache,                                   # k_cache, v_cache
                jax.ShapeDtypeStruct((b, S), f32),              # slot_mask
                jax.ShapeDtypeStruct((), i32),                  # k_dims
                jax.ShapeDtypeStruct((d,), f32),                # dim_keep
            ]

            def mk_decode(cfg=cfg, n=len(pspecs)):
                def fn(*args):
                    pl, rest = list(args[:n]), args[n:]
                    return M.decode_step(cfg, pl, *rest, use_pallas=True)
                return fn

            lower_fn(f"decode_b{b}", mk_decode(), decode_specs)

            C_chunk = PREFILL_CHUNK
            prefill_specs = pspecs + common + [
                jax.ShapeDtypeStruct((b, C_chunk), i32),        # tokens
                jax.ShapeDtypeStruct((b,), i32),                # pos0
                cache, cache,
                jax.ShapeDtypeStruct((b, S), f32),
                jax.ShapeDtypeStruct((), i32),
                jax.ShapeDtypeStruct((d,), f32),
            ]

            def mk_prefill(cfg=cfg, n=len(pspecs)):
                def fn(*args):
                    pl, rest = list(args[:n]), args[n:]
                    return M.prefill_chunk(cfg, pl, *rest, use_pallas=True)
                return fn

            lower_fn(f"prefill_b{b}_c{C_chunk}", mk_prefill(), prefill_specs)

        manifest["models"][name] = {
            "config": cfg.to_json_dict(),
            "params": params_path,
            "proj": proj_path,
            "calib_dump": dump_path,
            "param_order": sorted(params),
            "hlo": hlo_entries,
            "decode_batches": list(DECODE_BATCHES),
            "prefill_chunk": PREFILL_CHUNK,
            # decode outputs: (logits, k_cache, v_cache, attn_acc)
            # prefill outputs: (logits[B,C,V], k_cache, v_cache, slot_mask, attn_acc)
        }

    def relativize(obj):
        """Store all paths relative to the artifacts dir so the rust side
        can resolve them against the manifest's own location."""
        if isinstance(obj, dict):
            return {k: (os.path.relpath(v, artifacts) if k == "path" or
                        (isinstance(v, str) and v.endswith((".npz", ".hlo.txt", ".txt", ".jsonl")))
                        else relativize(v))
                    for k, v in obj.items()}
        if isinstance(obj, str) and obj.endswith((".npz", ".hlo.txt", ".jsonl")):
            return os.path.relpath(obj, artifacts)
        return obj

    manifest = relativize(manifest)
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    _log(f"wrote {manifest_path}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="tiny training run (CI smoke), not for experiments")
    args = ap.parse_args()
    build(args.artifacts, force=args.force, fast=args.fast)


if __name__ == "__main__":
    main()
